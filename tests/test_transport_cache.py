"""Descriptor-driven transport: compile-cache reuse across addresses,
coalescer semantics, deque completion paths, indexed responder lookup,
and ICITransport/LocalTransport parity (subprocess, forced multi-device).
"""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core.rdma import RDMAEngine, Opcode, WQE, coalesce_plan
from repro.core.rdma.verbs import QueuePair

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


def _random_plan(rng, n_wqes, n_peers=2, pool=64):
    """Random transfers including loopback and overlapping ranges."""
    plan = []
    for _ in range(n_wqes):
        ln = int(rng.integers(1, 9))
        plan.append(("xfer", int(rng.integers(0, n_peers)),
                     int(rng.integers(0, n_peers)),
                     int(rng.integers(0, pool - ln)),
                     int(rng.integers(0, pool - ln)), ln))
    return plan


def _fresh_transports(rng, n_peers=2, pool=64):
    import jax.numpy as jnp
    from repro.core.rdma.transport import make_transport
    init = rng.standard_normal((n_peers, pool)).astype(np.float32)
    a = make_transport(n_peers, pool)
    b = make_transport(n_peers, pool)
    a.pool = jnp.asarray(init)
    b.pool = jnp.asarray(init)
    return a, b


class TestCompileCache:
    def test_same_shape_fresh_addresses_reuse_one_compile(self):
        """20 address-varying batches of one shape profile -> 1 miss."""
        import jax.numpy as jnp
        from repro.core.rdma.transport import make_transport
        rng = np.random.default_rng(0)
        t = make_transport(2, 256)
        t.pool = jnp.asarray(rng.standard_normal((2, 256)), jnp.float32)
        for i in range(20):
            sa, da = int(rng.integers(0, 96)), int(rng.integers(128, 224))
            t.execute_batch([("xfer", 0, 1, sa, da, 30)])
        assert t.stats["dispatches"] == 20
        assert t.stats["cache_misses"] == 1
        assert t.stats["cache_hits"] == 19
        assert t.stats["compiles"] == 1

    def test_shape_buckets_pow2(self):
        from repro.core.rdma.transport import shape_buckets
        assert shape_buckets(1, 1, 4096) == (8, 16)
        assert shape_buckets(9, 33, 4096) == (16, 64)
        assert shape_buckets(50, 4000, 4096) == (64, 4096)
        # chunk never exceeds the pool's pow2 ceiling
        assert shape_buckets(1, 9999, 4096) == (8, 4096)

    def test_descriptor_matches_static_executor(self):
        """Byte-identical pools vs the seed executor on random plans
        (loopback + overlapping ranges included)."""
        rng = np.random.default_rng(42)
        for trial in range(12):
            a, b = _fresh_transports(rng)
            for _ in range(3):
                plan = _random_plan(rng, int(rng.integers(1, 12)))
                a.execute_batch(plan)
                b.execute_batch_static(plan)
            np.testing.assert_array_equal(
                np.asarray(a.pool), np.asarray(b.pool),
                err_msg=f"divergence on trial {trial}")


class TestCoalescer:
    def test_merges_contiguous_run(self):
        plan = [("xfer", 0, 1, i, 100 + i, 1) for i in range(50)]
        merged = coalesce_plan(plan)
        assert merged == [("xfer", 0, 1, 0, 100, 50)]

    def test_does_not_merge_direction_or_gap_changes(self):
        plan = [("xfer", 0, 1, 0, 100, 4),
                ("xfer", 1, 0, 4, 104, 4),    # direction flip
                ("xfer", 0, 1, 8, 108, 4),
                ("xfer", 0, 1, 13, 112, 4)]   # src gap
        assert len(coalesce_plan(plan)) == 4

    def test_loopback_overlap_not_merged(self):
        """On a loopback row, merging would change memcpy ordering when
        the combined ranges overlap — the guard must refuse."""
        plan = [("xfer", 0, 0, 0, 2, 4), ("xfer", 0, 0, 4, 6, 4)]
        assert len(coalesce_plan(plan)) == 2
        # disjoint loopback ranges do merge
        plan2 = [("xfer", 0, 0, 0, 32, 4), ("xfer", 0, 0, 4, 36, 4)]
        assert coalesce_plan(plan2) == [("xfer", 0, 0, 0, 32, 8)]

    def test_coalesced_semantics_equal_uncoalesced(self):
        """Random plans with contiguous runs: coalesced == original."""
        rng = np.random.default_rng(7)
        for _ in range(10):
            base = _random_plan(rng, int(rng.integers(1, 5)))
            # splice in a contiguous run
            sa, da = int(rng.integers(0, 16)), int(rng.integers(32, 48))
            run = [("xfer", 0, 1, sa + 2 * i, da + 2 * i, 2)
                   for i in range(4)]
            plan = base + run
            merged = coalesce_plan(plan)
            assert len(merged) <= len(plan)
            a, b = _fresh_transports(rng)
            a.execute_batch(plan)
            b.execute_batch(merged)
            np.testing.assert_array_equal(np.asarray(a.pool),
                                          np.asarray(b.pool))

    def test_engine_coalesces_contiguous_reads(self):
        eng = RDMAEngine(n_peers=2, pool_size=1024)
        qp = eng.create_qp(0, 1)
        eng.create_qp(1, 0)
        mr = eng.register_mr(1, 0, 512)
        eng.write_buffer(1, 0, np.arange(64, dtype=np.float32))
        for i in range(64):
            eng.post_send(qp, WQE(Opcode.READ, qp.qp_num, i,
                                  local_addr=512 + i, remote_addr=i,
                                  length=1, rkey=mr.rkey))
        eng.ring_sq_doorbell(qp)
        # 64 WQEs merged into ONE descriptor, still one dispatch
        assert eng.stats["coalesced_wqes"] == 63
        assert eng.stats["transport"]["coalesced_wqes"] == 63
        assert eng.transport.stats["wqes"] == 1
        assert eng.stats["wqes"] == 64          # verb-level count unchanged
        assert len(eng.poll_cq(qp, 64)) == 64   # every WQE completes
        np.testing.assert_array_equal(
            eng.read_buffer(0, 512, 64), np.arange(64, dtype=np.float32))


class TestCompletionPaths:
    def test_queue_pair_deque_window(self):
        """SQ holds only the unretired window; pending()/retire() are
        consistent with producer/doorbell/consumer indices."""
        qp = QueuePair(99, 0, 1)
        for i in range(6):
            qp.post_send(WQE(Opcode.WRITE, 99, i))
        qp.sq_doorbell = 4                       # doorbell covers 4 of 6
        pend = qp.pending()
        assert [w.wr_id for w in pend] == [0, 1, 2, 3]
        qp.retire(len(pend))
        assert qp.sq_cidx == 4 and len(qp.sq) == 2
        qp.sq_doorbell = 6
        assert [w.wr_id for w in qp.pending()] == [4, 5]

    def test_poll_cq_fifo_partial_drain(self):
        eng = RDMAEngine(n_peers=2, pool_size=512)
        qp = eng.create_qp(0, 1)
        eng.create_qp(1, 0)
        mr = eng.register_mr(1, 0, 256)
        for i in range(10):
            eng.post_send(qp, WQE(Opcode.READ, qp.qp_num, i,
                                  local_addr=256 + i, remote_addr=i,
                                  length=1, rkey=mr.rkey))
        eng.ring_sq_doorbell(qp)
        first = eng.poll_cq(qp, max_entries=3)
        rest = eng.poll_cq(qp, max_entries=64)
        assert [c.wr_id for c in first] == [0, 1, 2]
        assert [c.wr_id for c in rest] == list(range(3, 10))
        assert eng.poll_cq(qp) == []

    def test_recv_queue_fifo(self):
        eng = RDMAEngine(n_peers=2, pool_size=512)
        qp = eng.create_qp(0, 1)
        rqp = eng.create_qp(1, 0)
        eng.write_buffer(0, 0, np.arange(8, dtype=np.float32))
        for i in range(2):
            eng.post_recv(rqp, WQE(Opcode.RECV, rqp.qp_num, 100 + i,
                                   local_addr=64 + 16 * i, length=4))
        for i in range(2):
            eng.post_send(qp, WQE(Opcode.SEND, qp.qp_num, i,
                                  local_addr=4 * i, length=4))
        eng.ring_sq_doorbell(qp)
        rcqes = eng.poll_cq(rqp)
        assert [c.wr_id for c in rcqes] == [100, 101]  # RECVs in order
        np.testing.assert_array_equal(eng.read_buffer(1, 64, 4),
                                      [0, 1, 2, 3])
        np.testing.assert_array_equal(eng.read_buffer(1, 80, 4),
                                      [4, 5, 6, 7])


class TestResponderIndex:
    def test_matches_linear_scan_reference(self):
        eng = RDMAEngine(n_peers=4, pool_size=256)
        qps = [eng.create_qp(a, b) for a in range(4) for b in range(4)]
        qps += [eng.create_qp(0, 1), eng.create_qp(1, 0)]  # duplicates

        def reference(qp):
            for other in eng.qps.values():
                if (other.local_peer == qp.remote_peer
                        and other.remote_peer == qp.local_peer
                        and other.qp_num != qp.qp_num):
                    return other
            return None

        for qp in qps:
            assert eng._responder_qp(qp) is reference(qp)

    def test_loopback_qp_excludes_itself(self):
        eng = RDMAEngine(n_peers=2, pool_size=256)
        qp = eng.create_qp(0, 0)
        assert eng._responder_qp(qp) is None
        qp2 = eng.create_qp(0, 0)
        assert eng._responder_qp(qp) is qp2


def test_predict_from_stats_batching_wins():
    """The executed-stats bridge reproduces the paper's economics: one
    doorbell covering n WQEs beats n single-WQE doorbells."""
    from repro.core.rdma.simulator import predict_from_stats
    batched = predict_from_stats(
        {"dispatches": 1, "wqes": 50, "compiles": 1}, payload=4096)
    single = predict_from_stats(
        {"dispatches": 50, "wqes": 50, "compiles": 1}, payload=4096)
    assert batched["hw_predicted_s"] < single["hw_predicted_s"]
    assert batched["executor_predicted_s"] < single["executor_predicted_s"]
    assert batched["wqes_per_doorbell"] == 50.0


@pytest.mark.slow
def test_ici_transport_parity_and_cache(tmp_path):
    """ICITransport (forced 4-device mesh) matches LocalTransport byte
    for byte on an address-varying workload and reuses one compile."""
    code = """
import numpy as np
import jax.numpy as jnp
from repro.core.rdma.transport import (ICITransport, LocalTransport,
                                       make_transport)
rng = np.random.default_rng(0)
init = rng.standard_normal((4, 64)).astype(np.float32)
ici = make_transport(4, 64)
assert isinstance(ici, ICITransport), type(ici)
loc = LocalTransport(jnp.asarray(init))
ici.pool = jnp.asarray(init)
for _ in range(10):
    plan = []
    for _ in range(int(rng.integers(1, 6))):
        ln = int(rng.integers(1, 9))
        plan.append(("xfer", int(rng.integers(0, 4)), int(rng.integers(0, 4)),
                     int(rng.integers(0, 64 - ln)),
                     int(rng.integers(0, 64 - ln)), ln))
    ici.execute_batch(plan)
    loc.execute_batch(plan)
np.testing.assert_array_equal(np.asarray(ici.pool), np.asarray(loc.pool))
assert ici.stats["dispatches"] == 10
assert ici.stats["compiles"] <= 3, ici.stats   # few shape buckets only
print("ICI_PARITY_OK", ici.stats["compiles"])
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=560)
    assert "ICI_PARITY_OK" in r.stdout, r.stdout + r.stderr
