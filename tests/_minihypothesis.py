"""Minimal, dependency-free stand-in for the ``hypothesis`` API surface
these tests use (``given``/``settings``/``strategies``).

The container image has no ``hypothesis`` wheel and the project cannot
install packages, so ``conftest.py`` registers this module under the
``hypothesis`` name when the real library is absent.  It runs each
property deterministically over ``max_examples`` pseudo-random samples
(seeded per-test by the function name, so failures reproduce) and reports
the failing example like hypothesis does.  It is intentionally tiny: no
shrinking, no database, just sampling.
"""
from __future__ import annotations

import functools
import inspect
import random
import zlib


class SearchStrategy:
    def __init__(self, sample):
        self._sample = sample

    def sample(self, rng: random.Random):
        return self._sample(rng)

    def map(self, f):
        return SearchStrategy(lambda rng: f(self._sample(rng)))

    def filter(self, pred, _tries: int = 100):
        def draw(rng):
            for _ in range(_tries):
                v = self._sample(rng)
                if pred(v):
                    return v
            raise ValueError("filter predicate never satisfied")
        return SearchStrategy(draw)


class strategies:  # mirrors `from hypothesis import strategies as st`
    @staticmethod
    def integers(min_value=0, max_value=1 << 30):
        return SearchStrategy(lambda rng: rng.randint(min_value, max_value))

    @staticmethod
    def booleans():
        return SearchStrategy(lambda rng: rng.random() < 0.5)

    @staticmethod
    def floats(min_value=0.0, max_value=1.0):
        return SearchStrategy(
            lambda rng: rng.uniform(min_value, max_value))

    @staticmethod
    def sampled_from(seq):
        seq = list(seq)
        return SearchStrategy(lambda rng: rng.choice(seq))

    @staticmethod
    def lists(elements, min_size=0, max_size=10):
        def draw(rng):
            n = rng.randint(min_size, max_size)
            return [elements.sample(rng) for _ in range(n)]
        return SearchStrategy(draw)

    @staticmethod
    def tuples(*elements):
        return SearchStrategy(
            lambda rng: tuple(e.sample(rng) for e in elements))


def settings(max_examples: int = 100, deadline=None, **_ignored):
    def deco(fn):
        fn._mh_max_examples = max_examples
        return fn
    return deco


def given(*arg_strategies, **kw_strategies):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_mh_max_examples", 50)
            seed = zlib.crc32(fn.__qualname__.encode())
            rng = random.Random(seed)
            for i in range(n):
                drawn_args = tuple(s.sample(rng) for s in arg_strategies)
                drawn_kw = {k: s.sample(rng)
                            for k, s in kw_strategies.items()}
                try:
                    fn(*args, *drawn_args, **kwargs, **drawn_kw)
                except Exception as e:
                    raise AssertionError(
                        f"falsifying example ({i + 1}/{n}): "
                        f"args={drawn_args!r} kwargs={drawn_kw!r}") from e

        # Hide the strategy-supplied parameters from pytest's fixture
        # resolution (real hypothesis does the same): positional
        # strategies bind right-to-left, keyword strategies by name.
        params = list(inspect.signature(fn).parameters.values())
        if arg_strategies:
            params = params[:len(params) - len(arg_strategies)]
        params = [p for p in params if p.name not in kw_strategies]
        wrapper.__signature__ = inspect.Signature(params)
        return wrapper
    return deco


st = strategies
