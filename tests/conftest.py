import os
import sys

# Tests must see exactly ONE device (the dry-run forces 512 in its own
# subprocess only). Keep XLA flags clean here.
os.environ.pop("XLA_FLAGS", None)

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
