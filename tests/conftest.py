import os
import sys


def pytest_configure(config):
    # CI runs the fast tier first (-m "not slow"), then -m slow: a fast
    # failure short-circuits before any multi-device subprocess spawns.
    config.addinivalue_line(
        "markers",
        "slow: ICI-subprocess tests (forced multi-device meshes / driver "
        "e2e runs in child processes)")

# Tests must see exactly ONE device (the dry-run forces 512 in its own
# subprocess only). Keep XLA flags clean here.
os.environ.pop("XLA_FLAGS", None)

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import repro  # noqa: E402,F401  (installs the JAX forward-compat shims)

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)

# The container has no `hypothesis` wheel; register the minimal local
# stand-in so the property tests still run (see _minihypothesis.py).
try:
    import hypothesis  # noqa: F401
except ImportError:
    sys.path.insert(0, os.path.dirname(__file__))
    import _minihypothesis

    sys.modules["hypothesis"] = _minihypothesis
