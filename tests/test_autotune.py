"""Self-tuning transport (PR 10): the online bucket learner, the
``prewarm`` edge cases it sits on, the one ``TransportTuning`` knob
surface threaded through engine/lookaside/streaming, the per-QP flush
window, and the deterministic auto-sweep tuner."""
import numpy as np
import pytest

from repro.core.lookaside.registry import LookasideBlock
from repro.core.rdma.autotune import (AutoTuner, BucketLearner,
                                      TransportTuning, TuningGrid)
from repro.core.rdma.doorbell import schedule_plan
from repro.core.rdma.engine import RDMAEngine
from repro.core.rdma.simulator import predict_from_stats
from repro.core.rdma.verbs import Opcode, WQE
from repro.core.streaming.rx_ring import RXRing

POOL = 4096


def _engine(**kw):
    kw.setdefault("n_peers", 2)
    kw.setdefault("pool_size", POOL)
    return RDMAEngine(**kw)


def _post_reads(eng, qp, mr, lengths, rng=None):
    rng = rng or np.random.default_rng(0)
    for i, ln in enumerate(lengths):
        eng.post_send(qp, WQE(
            Opcode.READ, qp.qp_num, wr_id=i,
            local_addr=int(rng.integers(0, POOL // 4 - ln)),
            remote_addr=int(rng.integers(0, POOL // 4 - ln)),
            length=int(ln), rkey=mr.rkey))


# ---------------------------------------------------------------------------
# BucketLearner
# ---------------------------------------------------------------------------

class TestBucketLearner:
    def test_observe_and_predict_roundtrip(self):
        bl = BucketLearner()
        bl.observe(8, 32, n_wqes=3, max_len=20)
        assert bl.buckets() == [(8, 32)]
        assert (8, 32) in bl.predict()

    def test_pow2_adjacent_spans_merge_with_counter(self):
        bl = BucketLearner()
        bl.observe(8, 16)
        bl.observe(8, 32)                    # adjacent pow2: one span
        assert bl.stats["bucket_merges"] == 1
        assert bl.buckets() == [(8, 16), (8, 32)]   # span covers both
        bl.observe(8, 64)
        assert bl.stats["bucket_merges"] == 2
        assert (8, 64) in bl.buckets()

    def test_distant_chunks_stay_separate_spans(self):
        bl = BucketLearner()
        bl.observe(8, 16)
        bl.observe(8, 1024)                  # not adjacent: no merge
        assert bl.stats["bucket_merges"] == 0
        assert bl.buckets() == [(8, 16), (8, 1024)]

    def test_decay_evicts_stale_buckets_with_counter(self):
        bl = BucketLearner(decay=0.5, min_weight=0.1)
        bl.observe(8, 16)
        for _ in range(8):                   # 0.5^8 << 0.1: (8,16) ages out
            bl.observe(64, 1024)
        assert bl.stats["bucket_decay_events"] >= 1
        assert (8, 16) not in bl.buckets()
        assert (64, 1024) in bl.buckets()

    def test_current_bucket_never_self_evicts(self):
        bl = BucketLearner(decay=0.5, min_weight=0.1)
        for _ in range(20):                  # weight decays each observe,
            bl.observe(8, 16)                # but the live bucket stays
        assert (8, 16) in bl.buckets()
        assert bl.stats["bucket_decay_events"] == 0

    def test_fill_widens_chunk_axis_one_pow2_up(self):
        bl = BucketLearner(widen_threshold=0.75)
        bl.observe(8, 64, n_wqes=2, max_len=48)      # 48/64 = 0.75 fill
        assert (8, 128) in bl.predict()
        assert (8, 128) not in bl.buckets()          # prediction, not data

    def test_low_fill_does_not_widen(self):
        bl = BucketLearner(widen_threshold=0.75)
        bl.observe(8, 64, n_wqes=2, max_len=20)
        assert (8, 128) not in bl.predict()

    def test_full_slots_widen_slot_axis(self):
        bl = BucketLearner(widen_threshold=0.75)
        bl.observe(8, 32, n_wqes=8, max_len=10)      # 8/8 slots full
        assert (16, 32) in bl.predict()

    def test_stats_dict_is_shared_surface(self):
        stats = {"bucket_decay_events": 0, "bucket_merges": 0,
                 "learned_buckets": 0}
        bl = BucketLearner(stats=stats)
        bl.observe(8, 16)
        bl.observe(8, 32)
        assert stats["bucket_merges"] == 1
        assert stats["learned_buckets"] == 2


# ---------------------------------------------------------------------------
# transport.prewarm edge cases (the path the learner sits on)
# ---------------------------------------------------------------------------

class TestPrewarmEdgeCases:
    def test_oversized_chunk_key_clamped_like_shape_buckets(self):
        eng = _engine()
        t = eng.transport
        assert t.prewarm(["8x8192"]) == 1    # pool 4096: clamps to 4096
        assert (8, POOL) in t._seen_buckets
        assert (8, 8192) not in t._seen_buckets
        assert t.stats["prewarmed_buckets"] == 1

    def test_duplicate_keys_not_double_counted(self):
        eng = _engine()
        t = eng.transport
        n = t.prewarm(["8x16", "8x16", (8, 16), ("8", "16")])
        assert n == 1
        assert t.stats["prewarmed_buckets"] == 1
        # clamped duplicates collapse onto the same key too
        assert t.prewarm(["8x8192", (8, 4096), "8x999999"]) == 1
        assert t.stats["prewarmed_buckets"] == 2

    def test_prewarmed_vs_seen_vs_hit_accounting(self):
        eng = _engine()
        t = eng.transport
        t.prewarm([(8, 16)])
        assert t.stats["prewarmed_buckets"] == 1
        assert t.stats["dispatches"] == 0    # prewarm is not a dispatch
        assert t.stats["cache_misses"] == 0
        t.execute_batch([("xfer", 0, 1, 0, 64, 7)])   # keys on (8, 16)
        assert t.stats["cache_hits"] == 1    # prewarm made it a hit
        assert t.stats["cache_misses"] == 0
        assert t.stats["prewarmed_buckets"] == 1      # unchanged
        assert t._seen_buckets == {(8, 16)}

    def test_prewarm_none_reads_own_learner(self):
        eng = _engine()
        t = eng.transport
        t.execute_batch([("xfer", 0, 1, 0, 64, 7)])
        assert t.prewarm() == 0              # own traffic already compiled
        # widened predictions ARE newly warmed: near-full chunk fill
        t.execute_batch([("xfer", 0, 1, i * 16, 2048 + i * 16, 15)
                         for i in range(8)])  # (8,16) @ 15/16 fill, 8/8
        assert t.prewarm() > 0               # (8,32)/(16,*) widened out

    def test_prewarm_from_another_transports_learner(self):
        a, b = _engine().transport, _engine().transport
        a.execute_batch([("xfer", 0, 1, 0, 64, 30)])
        assert b.prewarm(a.bucket_learner) >= 1
        b.execute_batch([("xfer", 0, 1, 8, 80, 30)])
        assert b.stats["cache_misses"] == 0

    def test_prewarm_leaves_pool_bytes_untouched(self):
        eng = _engine()
        eng.transport.host_write(0, 0, np.arange(32, dtype=np.float32))
        before = np.asarray(eng.transport.pool).copy()
        eng.transport.prewarm(["8x64", "16x128"])
        assert np.array_equal(np.asarray(eng.transport.pool), before)


# ---------------------------------------------------------------------------
# TransportTuning threading (the consolidated knob surface)
# ---------------------------------------------------------------------------

class TestTuningThreading:
    def test_engine_defaults_are_historical_hand_picked_values(self):
        eng = _engine()
        assert eng.tuning == TransportTuning()
        assert eng.tuning.ring_burst == 32
        assert eng.tuning.pipeline_depth == 1
        assert eng.flush_budget is None and eng.qp_window is None

    def test_explicit_kwargs_win_over_tuning(self):
        eng = _engine(flush_budget=8,
                      tuning=TransportTuning(flush_budget=4, qp_window=2))
        assert eng.flush_budget == 8         # kwarg wins
        assert eng.qp_window == 2            # tuning fills the rest

    def test_tuning_seeds_flush_budget_and_window(self):
        eng = _engine(tuning=TransportTuning(flush_budget=6, qp_window=3))
        assert eng.flush_budget == 6 and eng.qp_window == 3

    def test_apply_tuning_updates_live_knobs(self):
        eng = _engine()
        eng.apply_tuning(TransportTuning(flush_budget=16, qp_window=4))
        assert eng.flush_budget == 16 and eng.qp_window == 4
        assert eng.tuning.flush_budget == 16

    def test_block_inherits_engine_tuning_pipeline_depth(self):
        eng = _engine(tuning=TransportTuning(pipeline_depth=4))
        blk = LookasideBlock(eng)
        assert blk.pipeline_depth == 4
        assert blk.tuning.pipeline_depth == 4
        explicit = LookasideBlock(_engine(
            tuning=TransportTuning(pipeline_depth=4)), pipeline_depth=2)
        assert explicit.pipeline_depth == 2  # explicit kwarg wins

    def test_registry_line96_hardcode_is_gone(self):
        """The satellite fix: ring_burst threads from TransportTuning
        instead of the old ``self.ring_burst = 32`` literal."""
        eng = _engine(tuning=TransportTuning(ring_burst=8))
        blk = LookasideBlock(eng)
        k = blk.register(1, lambda ctx: None)
        assert k.ring_burst == 8             # from tuning, not hardcoded
        k2 = blk.register(2, lambda ctx: None, ring_burst=64)
        assert k2.ring_burst == 64           # explicit still wins

    def test_attach_ring_none_burst_keeps_tuned_value(self):
        eng = _engine(pool_size=16384,
                      tuning=TransportTuning(ring_burst=8))
        blk = LookasideBlock(eng)

        def fn(ctx, start, count):
            return None

        blk.register(1, fn)
        ring = RXRing(eng, peer=0)
        out_mr = eng.register_mr(0, 0, 512)
        k = blk.attach_ring(1, ring, 0, out_mr.rkey, 0)
        assert k.ring_burst == 8             # tuned default preserved
        assert k.dispatcher.burst == 8
        k_explicit = blk.register(2, fn)
        blk.attach_ring(2, RXRing(eng, peer=0, base=0), 0, out_mr.rkey,
                        0, burst=4)
        assert k_explicit.ring_burst == 4    # explicit still wins

    def test_rx_ring_depth_from_tuning(self):
        eng = _engine(pool_size=16384,
                      tuning=TransportTuning(rx_depth=16))
        ring = RXRing(eng, peer=0)
        assert ring.depth == 16
        assert RXRing(eng, peer=0, depth=8).depth == 8   # explicit wins
        assert RXRing(_engine(pool_size=16384), peer=0).depth == 64


# ---------------------------------------------------------------------------
# qp_window (the per-QP flush share bound)
# ---------------------------------------------------------------------------

class TestQPWindow:
    def test_schedule_plan_caps_per_qp_picks(self):
        windows = [(1, list(range(6))), (2, list(range(2)))]
        order, counts = schedule_plan(windows, scheduler="fifo",
                                      qp_window=2)
        assert counts == {1: 2, 2: 2}
        assert [e for q, e in order if q == 1] == [0, 1]   # prefix rule

    def test_qp_window_is_orthogonal_to_budget(self):
        windows = [(1, list(range(6))), (2, list(range(6)))]
        _, counts = schedule_plan(windows, scheduler="rr", budget=10,
                                  qp_window=3)
        assert counts == {1: 3, 2: 3}        # window binds before budget
        _, counts = schedule_plan(windows, scheduler="rr", budget=4,
                                  qp_window=3)
        assert sum(counts.values()) == 4     # budget binds when tighter

    def test_engine_flush_respects_qp_window(self):
        eng = _engine(qp_window=2, scheduler="fifo")
        mr = eng.register_mr(1, 0, 1024)
        qp = eng.create_qp(0, 1)
        _post_reads(eng, qp, mr, [8] * 6)
        eng.ring_sq_doorbell(qp, defer=True)
        assert eng.flush_doorbells() == {qp.qp_num: 2}
        assert qp.pending_count == 4         # leftovers stay armed
        assert eng.flush_doorbells() == {qp.qp_num: 2}

    def test_window_limit_is_min_of_budget_and_window(self):
        assert _engine()._window_limit() is None
        assert _engine(flush_budget=8)._window_limit() == 8
        assert _engine(qp_window=4)._window_limit() == 4
        assert _engine(flush_budget=8, qp_window=4)._window_limit() == 4
        assert _engine(flush_budget=2, qp_window=4)._window_limit() == 2


# ---------------------------------------------------------------------------
# AutoTuner (small grids: the full sweep lives in bench_autotune)
# ---------------------------------------------------------------------------

SMALL_GRID = TuningGrid(ring_burst=(16, 32), pipeline_depth=(1, 2),
                        flush_budget=(None,), qp_window=(None,))


class TestAutoTuner:
    def _live_engine(self):
        eng = _engine()
        mr = eng.register_mr(1, 0, 1024)
        qp = eng.create_qp(0, 1)
        _post_reads(eng, qp, mr, [7, 20, 33])
        eng.ring_sq_doorbell(qp)
        return eng

    def test_trials_are_memoized_per_point(self):
        eng = self._live_engine()
        tuner = AutoTuner(eng, grid=SMALL_GRID, seed=3, passes=1, rows=16)
        a = tuner.measure(TransportTuning())
        b = tuner.measure(TransportTuning())
        assert a is b
        assert len(tuner.surface) == 1

    def test_sweep_result_lands_in_engine_stats(self):
        eng = self._live_engine()
        tuner = AutoTuner(eng, grid=SMALL_GRID, seed=3, passes=1, rows=16)
        chosen = tuner.sweep()
        at = eng.stats["autotune"]
        assert at["chosen"] == chosen.as_dict()
        assert at["seed"] == 3
        assert at["trials"] == len(at["surface"]) == len(tuner.surface)
        assert at["score"] >= at["default_score"]          # grid holds
        assert at["improvement"] >= 1.0 - 1e-9             # the default
        assert eng.tuning == chosen          # sweep() applied it

    def test_same_seed_sweeps_choose_identically(self):
        eng = self._live_engine()
        c1 = AutoTuner(eng, grid=SMALL_GRID, seed=5, passes=1,
                       rows=16).sweep(apply=False)
        c2 = AutoTuner(eng, grid=SMALL_GRID, seed=5, passes=1,
                       rows=16).sweep(apply=False)
        assert c1 == c2

    def test_trial_counts_not_wallclock_drive_the_score(self):
        eng = self._live_engine()
        tuner = AutoTuner(eng, grid=SMALL_GRID, seed=3, passes=1, rows=16)
        res = tuner.measure(TransportTuning())
        assert res.score == pytest.approx(res.rows / res.modeled_s)
        assert res.modeled_s > 0 and res.wall_s > 0
        assert res.flushes > 0 and res.wqes > 0

    def test_predict_from_stats_threads_autotune_terms(self):
        eng = self._live_engine()
        AutoTuner(eng, grid=SMALL_GRID, seed=3, passes=1, rows=16).sweep()
        out = predict_from_stats(eng.stats, payload=128)
        assert out["autotune_trials"] >= 3
        assert out["autotune_improvement"] >= 1.0 - 1e-9
        assert out["autotune_chosen_ring_burst"] in (16.0, 32.0)
        assert out["learned_buckets"] >= 1.0

    def test_stats_without_autotune_have_no_terms(self):
        eng = self._live_engine()
        out = predict_from_stats(eng.stats, payload=128)
        assert "autotune_trials" not in out
        assert out["learned_buckets"] >= 1.0  # learner always observes
