"""Collective conformance: gradient-bucket all-reduce as scheduled RDMA
verbs (train.collectives) vs the ``jax.lax.psum`` oracle.

Pins the PR's hard claims: byte-identical reductions across algorithms,
dtype mixes, and non-pow2 peer counts; zero steady-state compiles; byte
parity under seeded drop (retransmits reuse the warmed shape buckets);
and DRR fairness — a streaming gradient collective must not skew service
between equal-weight serving tenants (Jain == 1.0).
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.rdma.cost_model import jain_fairness_index
from repro.core.rdma.engine import RDMAEngine
from repro.core.rdma.reliability import FaultInjector
from repro.core.rdma.verbs import Opcode, WQE
from repro.train.collectives import (CollectiveError, RDMACollective,
                                     ideal_wire_words)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


def _engine(n: int, pool: int = 1 << 14, **kw) -> RDMAEngine:
    return RDMAEngine(n_peers=max(n, 2), pool_size=pool, **kw)


def _psum_oracle(shards) -> np.ndarray:
    """All-reduce oracle: vmap over a named axis — the same lax.psum the
    abstract bucketed path uses, no multi-device mesh needed."""
    stacked = jnp.stack([jnp.asarray(s, jnp.float32) for s in shards])
    return np.asarray(jax.vmap(lambda x: jax.lax.psum(x, "p"),
                               axis_name="p")(stacked))


def _int_shards(rng, n: int, words: int):
    """Integer-valued f32 shards: sums are exact under ANY reduction
    order, so parity checks can demand bitwise equality."""
    return [rng.integers(-8, 9, words).astype(np.float32)
            for _ in range(n)]


@pytest.mark.parametrize("n", [2, 3, 4, 5, 8])
@pytest.mark.parametrize("algorithm", ["ring", "rd"])
def test_allreduce_parity(n, algorithm):
    """Byte parity vs psum across pow2 and non-pow2 peer counts, and the
    wire-word ledger must match the α–β ideal exactly."""
    rng = np.random.default_rng(n)
    words = 100                        # non-multiple of n: padding path
    eng = _engine(n)
    coll = RDMACollective(eng, n, algorithm=algorithm)
    shards = _int_shards(rng, n, words)
    got = coll.all_reduce(shards)
    want = _psum_oracle(shards)
    for p in range(n):
        assert np.array_equal(got[p][:words], want[p]), (algorithm, n, p)
    assert coll.stats["wire_words"] == ideal_wire_words(
        algorithm, n, words)


def test_allreduce_parity_dtype_mix():
    """Grad pytrees mix fp32/bf16/int8 leaves; all land in f32 pool words
    and the reduction stays exact for integer-valued payloads."""
    rng = np.random.default_rng(0)
    n = 4
    leaves = {
        "w": (np.float32, 96), "h": (jnp.bfloat16, 64),
        "r": (np.int8, 32),
    }
    per_peer = []
    for p in range(n):
        vecs = [np.asarray(
            jnp.asarray(rng.integers(-4, 5, size), dt), np.float32)
            for dt, size in leaves.values()]
        per_peer.append(np.concatenate(vecs))
    eng = _engine(n)
    coll = RDMACollective(eng, n)
    got = coll.all_reduce(per_peer)
    want = _psum_oracle(per_peer)
    words = per_peer[0].size
    for p in range(n):
        assert np.array_equal(got[p][:words], want[p])


def test_reduce_scatter_all_gather_pair():
    """The ZeRO-1 boundary: RS hands each peer its owned reduced chunk;
    AG of those chunks reconstructs the full sum everywhere."""
    rng = np.random.default_rng(1)
    n, words = 4, 128                  # multiple of n: no padding
    eng = _engine(n)
    coll = RDMACollective(eng, n)
    shards = _int_shards(rng, n, words)
    want = _psum_oracle(shards)
    chunks = coll.reduce_scatter(shards)
    cw = words // n
    for p in range(n):                 # peer p owns chunk (p+1) mod n
        own = (p + 1) % n
        assert np.array_equal(chunks[p], want[p][own * cw:(own + 1) * cw])
    full = coll.all_gather(chunks)
    for p in range(n):
        assert np.array_equal(full[p], want[p])


def test_zero_warm_compiles_across_steps():
    """Repeated steps ride cached descriptor programs: after the first
    all-reduce, later ones must add ZERO descriptor or QDMA compiles."""
    rng = np.random.default_rng(2)
    n = 4
    eng = _engine(n)
    coll = RDMACollective(eng, n)
    coll.all_reduce(_int_shards(rng, n, 256))          # warm-up
    c0 = eng.stats["transport"]["compiles"]
    q0 = eng.stats["transport"]["qdma_compiles"]
    for _ in range(3):
        coll.all_reduce(_int_shards(rng, n, 256))
    assert eng.stats["transport"]["compiles"] == c0
    assert eng.stats["transport"]["qdma_compiles"] == q0


def test_retransmit_under_seeded_drop_parity():
    """10% seeded drop: chunk READs retransmit go-back-N through the
    same shape buckets — byte parity and zero new compiles."""
    rng = np.random.default_rng(3)
    n = 3
    eng = _engine(n)
    eng.install_fault_injector(FaultInjector(7, drop=0.10))
    coll = RDMACollective(eng, n)
    shards = _int_shards(rng, n, 96)
    got = coll.all_reduce(shards)               # warm-up (faulted too)
    want = _psum_oracle(shards)
    for p in range(n):
        assert np.array_equal(got[p][:96], want[p])
    c0 = eng.stats["transport"]["compiles"]
    q0 = eng.stats["transport"]["qdma_compiles"]
    shards2 = _int_shards(rng, n, 96)
    got2 = coll.all_reduce(shards2)
    want2 = _psum_oracle(shards2)
    for p in range(n):
        assert np.array_equal(got2[p][:96], want2[p])
    rel = eng.stats.get("reliability", {})
    assert rel.get("retransmits", 0) > 0, "drop profile never fired"
    assert eng.stats["transport"]["compiles"] == c0
    assert eng.stats["transport"]["qdma_compiles"] == q0


def test_overlapped_flushes_with_multiple_buckets():
    """pipeline_depth=2 over 4 buckets: consecutive buckets' rounds must
    share flushes (the comm/compute overlap ledger)."""
    rng = np.random.default_rng(4)
    n = 2
    eng = _engine(n, pool=1 << 15)
    coll = RDMACollective(eng, n, pipeline_depth=2)
    buckets = [_int_shards(rng, n, 256) for _ in range(4)]
    got = coll.all_reduce_buckets(buckets)
    for b in range(4):
        want = _psum_oracle(buckets[b])
        for p in range(n):
            assert np.array_equal(got[b][p][:256], want[p])
    assert coll.stats["overlapped_flushes"] > 0
    assert coll.stats["flushes"] >= coll.stats["overlapped_flushes"]


def test_drr_serving_fairness_while_training_streams():
    """Collective QPs are ordinary DRR tenants: two equal-weight serving
    QPs streaming alongside a gradient all-reduce split the engine
    evenly (Jain over their service == 1.0)."""
    eng = _engine(2, pool=1 << 14, scheduler="drr", flush_budget=6)
    hi = eng.pool_size - 512            # serving arena, above collective
    eng.register_mr(0, hi, 256)
    src = eng.register_mr(1, hi, 256)
    qa = eng.create_qp(0, 1, weight=2)
    qb = eng.create_qp(0, 1, weight=2)
    for i in range(24):                 # equal backlogs, armed deferred
        for qp in (qa, qb):
            eng.post_send(qp, WQE(Opcode.READ, qp.qp_num, wr_id=9000 + i,
                                  local_addr=hi, remote_addr=src.base,
                                  length=4, rkey=src.rkey))
            eng.ring_sq_doorbell(qp, defer=True)
    rng = np.random.default_rng(5)
    coll = RDMACollective(eng, 2, weight=2, pipeline_depth=2)
    buckets = [_int_shards(rng, 2, 256) for _ in range(3)]
    got = coll.all_reduce_buckets(buckets)
    for b in range(3):
        want = _psum_oracle(buckets[b])
        assert np.array_equal(got[b][0][:256], want[0])
    served = [eng.stats["qp_service"].get(q.qp_num, 0) for q in (qa, qb)]
    assert served[0] > 0, "serving tenants never interleaved"
    assert jain_fairness_index(served) == 1.0, served


def test_collective_error_surfaces_statuses():
    """A peer failure mid-collective raises CollectiveError (terminal
    CQEs, not silent corruption)."""
    rng = np.random.default_rng(6)
    eng = _engine(2)
    inj = eng.install_fault_injector(FaultInjector(0))
    coll = RDMACollective(eng, 2, max_flushes=8)
    inj.stall_peer(1)
    with pytest.raises(CollectiveError):
        coll.all_reduce(_int_shards(rng, 2, 64))


def test_bucketize_bills_dtype_itemsize():
    """Regression (satellite 1): bucket planning must bill bf16 leaves 2
    bytes/elem and int8 1 — never a hardcoded 4."""
    from repro.train.train_step import _bucketize
    grads = {
        "a": jnp.zeros(100, jnp.float32),    # 400 B
        "b": jnp.zeros(100, jnp.bfloat16),   # 200 B
        "c": jnp.zeros(100, jnp.int8),       # 100 B
    }
    leaves, _, buckets = _bucketize(grads, 512)
    assert sum(b.bytes for b in buckets) == 700
    # old *4 billing would refuse to pair ANY two leaves under 512 B
    assert len(buckets) == 2, [b.bytes for b in buckets]


def test_compress_without_residuals_raises():
    """Regression (satellite 3): compress=True with no error-feedback
    state must raise, never silently ship uncompressed fp32."""
    from repro.train.train_step import bucketed_sync
    grads = {"w": jnp.ones(8, jnp.float32)}
    with pytest.raises(ValueError, match="residuals"):
        bucketed_sync(grads, ("data",), 1 << 20, compress=True,
                      residuals=None)


@pytest.mark.slow
def test_rdma_train_step_end_to_end():
    """sync='rdma': the bucketed train step's gradient sync rides the
    engine — loss decreases, zero warm compiles across steps."""
    from repro.configs.base import TrainConfig
    from repro.configs.registry import get_config
    from repro.models import init_params
    from repro.train import init_adam
    from repro.train.train_step import make_bucketed_train_step
    cfg = get_config("tiny")
    tcfg = TrainConfig(remat=False, zero1=False, sequence_parallel=False,
                       grad_bucket_mb=0.0625)
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = init_adam(params)
    step = make_bucketed_train_step(cfg, tcfg, None, sync="rdma",
                                    n_peers=2)
    batch = {"tokens": jnp.ones((4, 32), jnp.int32),
             "labels": jnp.ones((4, 32), jnp.int32)}
    loss1, p1, o1, _ = step(params, opt, batch, None)
    eng = step.collective(0).engine
    c0 = eng.stats["transport"]["compiles"]
    q0 = eng.stats["transport"]["qdma_compiles"]
    loss2, _, _, _ = step(p1, o1, batch, None)
    assert np.isfinite(float(loss1))
    assert float(loss2) < float(loss1), (float(loss1), float(loss2))
    assert eng.stats["transport"]["compiles"] == c0
    assert eng.stats["transport"]["qdma_compiles"] == q0
    assert eng.stats["collectives"]["overlapped_flushes"] > 0
    assert eng.stats["collectives"]["wire_bytes"] > 0


@pytest.mark.slow
def test_allreduce_parity_ici_transport():
    """Same parity claim on the REAL sharded-pool transport (4 forced
    host devices -> ICITransport), in a subprocess."""
    code = """
import numpy as np
from repro.core.rdma.engine import RDMAEngine
from repro.train.collectives import RDMACollective
rng = np.random.default_rng(0)
n = 4
eng = RDMAEngine(n_peers=n, pool_size=1 << 12)
assert type(eng.transport).__name__ == 'ICITransport', type(eng.transport)
coll = RDMACollective(eng, n)
shards = [rng.integers(-8, 9, 96).astype(np.float32) for _ in range(n)]
want = np.sum(shards, axis=0)
got = coll.all_reduce(shards)
for p in range(n):
    assert np.array_equal(got[p][:96], want), p
print('ICI_COLL_OK')
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=560)
    assert "ICI_COLL_OK" in r.stdout, r.stdout + r.stderr
