"""Per-architecture smoke tests (reduced configs) + model-math unit tests.

Every assigned arch: instantiate the reduced same-family config, run one
forward and one train step on CPU, assert output shapes + no NaNs + loss
decreases over a few memorization steps (train path exercises remat).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import TrainConfig
from repro.configs.registry import ARCHS, get_config
from repro.models import forward, init_caches, init_params
from repro.models.ssm import _ssd_chunked
from repro.serve import decode_step, prefill_step
from repro.train import init_adam, make_train_step

ALL_SMOKE = [a + "-smoke" for a in ARCHS]


def _batch_for(cfg, b=2, s=32, key=0):
    rng = np.random.default_rng(key)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)),
                              jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)),
                              jnp.int32),
    }
    if cfg.mrope:
        batch["mrope_positions"] = jnp.broadcast_to(
            jnp.arange(s, dtype=jnp.int32), (3, b, s))
        batch["patch_embeds"] = jnp.asarray(
            rng.normal(size=(b, s // cfg.vision_patches_ratio,
                             cfg.d_model)), jnp.float32)
    if cfg.enc_dec:
        batch["enc_embeds"] = jnp.asarray(
            rng.normal(size=(b, s // cfg.encoder_seq_ratio, cfg.d_model)),
            jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ALL_SMOKE)
def test_arch_smoke_forward(arch):
    cfg = get_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch_for(cfg)
    logits, _, aux = forward(params, cfg, batch)
    assert logits.shape == (2, 32, cfg.padded_vocab())
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"
    if cfg.moe.enabled:
        assert float(aux) > 0.0


@pytest.mark.parametrize("arch", ["qwen3-4b-smoke", "mamba2-370m-smoke",
                                  "deepseek-v2-lite-16b-smoke",
                                  "hymba-1.5b-smoke",
                                  "seamless-m4t-large-v2-smoke"])
def test_arch_smoke_train_step(arch):
    cfg = get_config(arch)
    tcfg = TrainConfig(learning_rate=1e-3, warmup_steps=1, total_steps=10,
                       remat=True, zero1=False, sequence_parallel=False)
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = init_adam(params)
    step = jax.jit(make_train_step(cfg, tcfg))
    batch = _batch_for(cfg)
    losses = []
    for _ in range(5):
        loss, params, opt = step(params, opt, batch)
        losses.append(float(loss))
    assert np.isfinite(losses).all(), f"{arch}: NaN loss {losses}"
    assert losses[-1] < losses[0], f"{arch}: no learning {losses}"


@pytest.mark.parametrize("arch", ["tiny", "tiny-ssm",
                                  "deepseek-v2-lite-16b-smoke",
                                  "hymba-1.5b-smoke",
                                  "phi3.5-moe-42b-smoke"])
def test_prefill_decode_matches_full_forward(arch):
    cfg = get_config(arch)
    if cfg.moe.enabled:   # disable capacity drops for exactness
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch_for(cfg, b=2, s=16)
    toks = batch["tokens"]
    extra = {k: v for k, v in batch.items()
             if k in ("enc_embeds",)}
    full, _, _ = forward(params, cfg, {"tokens": toks, **extra})
    caches = init_caches(cfg, 2, 16, jnp.float32)
    lg, caches = prefill_step(params, cfg,
                              {"tokens": toks[:, :12], **extra}, caches)
    errs = [float(jnp.abs(lg[:, -1] - full[:, 11]).max())]
    for i in range(12, 16):
        lg, caches = decode_step(params, cfg, toks[:, i:i + 1], caches,
                                 jnp.int32(i), extra=extra or None)
        errs.append(float(jnp.abs(lg[:, 0] - full[:, i]).max()))
    assert max(errs) < 5e-5, f"{arch}: decode mismatch {errs}"


def test_ssd_chunked_equals_recurrent():
    rng = np.random.default_rng(0)
    b, s, nh, hd, g, n, chunk = 2, 32, 4, 8, 2, 16, 8
    xh = jnp.asarray(rng.normal(size=(b, s, nh, hd)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.1, 0.9, (b, s, nh)), jnp.float32)
    a = -jnp.asarray(rng.uniform(0.5, 2.0, (nh,)), jnp.float32)
    B = jnp.asarray(rng.normal(size=(b, s, g, n)), jnp.float32)
    C = jnp.asarray(rng.normal(size=(b, s, g, n)), jnp.float32)
    y, fin = _ssd_chunked(xh, dt, a, B, C, chunk)
    Bh = jnp.repeat(B, nh // g, axis=2)
    Ch = jnp.repeat(C, nh // g, axis=2)
    S = np.zeros((b, nh, hd, n), np.float32)
    ys = []
    for t in range(s):
        dec = np.exp(np.asarray(dt[:, t]) * np.asarray(a))
        S = S * dec[:, :, None, None] + np.einsum(
            "bh,bhn,bhd->bhdn", np.asarray(dt[:, t]),
            np.asarray(Bh[:, t]), np.asarray(xh[:, t]))
        ys.append(np.einsum("bhn,bhdn->bhd", np.asarray(Ch[:, t]), S))
    np.testing.assert_allclose(np.asarray(y), np.stack(ys, 1),
                               rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(np.asarray(fin), S, rtol=2e-4, atol=2e-5)


def test_moe_capacity_drops_are_bounded():
    """With capacity_factor=1.0 at most cap tokens land per expert."""
    from repro.models.moe import _dispatch_indices
    rng = np.random.default_rng(1)
    t, k, e, cap = 64, 2, 4, 32
    idx = jnp.asarray(rng.integers(0, e, (t, k)), jnp.int32)
    pos, keep = _dispatch_indices(idx, k, e, cap)
    pos, keep, idx = map(np.asarray, (pos, keep, idx))
    for ee in range(e):
        kept = keep & (idx == ee)
        assert kept.sum() <= cap
        # positions within an expert are unique
        ps = pos[kept]
        assert len(set(ps.tolist())) == len(ps)


def test_mrope_equals_rope_for_text_only():
    """When t/h/w position ids are identical, M-RoPE == standard RoPE."""
    from repro.models.layers import apply_mrope, apply_rope
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(2, 8, 4, 16)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(8, dtype=jnp.int32), (2, 8))
    mpos = jnp.stack([pos, pos, pos])
    a = apply_rope(x, pos, 10_000.0)
    b = apply_mrope(x, mpos, 10_000.0, (2, 3, 3))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_sliding_window_layer_schedule():
    from repro.models.transformer import layer_windows
    cfg = get_config("hymba-1.5b")
    w = np.asarray(layer_windows(cfg, cfg.num_layers))
    assert w[0] == 0 and w[-1] == 0          # global first/last
    assert w[16] == 0                         # every 16th global
    assert (w[1:16] == cfg.sliding_window).all()


def test_vocab_padding_roundtrip():
    cfg = get_config("mamba2-370m")
    assert cfg.padded_vocab() % 256 == 0
    assert cfg.padded_vocab() >= cfg.vocab_size
    smoke = get_config("tiny")
    assert smoke.padded_vocab() == 256        # already aligned
