"""Paper-claim validation: the discrete-event simulator must reproduce the
measured anchors of §VI (Figs 8-12) — the reproduction's ground truth —
plus JSON-testcase regression (the paper's §V framework analogue)."""
import glob
import os

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.rdma.cost_model import PAPER_HW, jain_fairness_index
from repro.core.rdma.simulator import (run_testcase, simulate_dma,
                                       simulate_fair_schedule,
                                       simulate_host_access, simulate_rdma)

TESTCASE_DIR = os.path.join(os.path.dirname(__file__), "testcases")


class TestPaperAnchors:
    """Each anchor is a number stated in the paper's text."""

    def test_read_single_16k_is_18gbps(self):
        r = simulate_rdma("read", 16384, 1)
        assert abs(r.throughput_bps / 1e9 - 18.0) < 18.0 * 0.10

    def test_read_batch_16k_is_89gbps(self):
        r = simulate_rdma("read", 16384, 50)
        assert abs(r.throughput_bps / 1e9 - 89.0) < 89.0 * 0.05

    def test_read_batch_32k_near_line_rate(self):
        r = simulate_rdma("read", 32768, 50)
        assert abs(r.throughput_bps / 1e9 - 92.0) < 92.0 * 0.05
        assert r.throughput_bps < 100e9           # never above line rate

    def test_batch_small_latency_approx_400ns(self):
        r = simulate_rdma("read", 4096, 50)
        assert 0.2e-6 <= r.latency_per_op <= 0.55e-6

    def test_batch_latency_10x_better_small(self):
        single = simulate_rdma("read", 4096, 1)
        batch = simulate_rdma("read", 4096, 50)
        assert single.latency_per_op / batch.latency_per_op >= 8.0

    def test_write_trends_similar_to_read(self):
        for size in (4096, 16384, 65536):
            rd = simulate_rdma("read", size, 50)
            wr = simulate_rdma("write", size, 50)
            assert abs(wr.throughput_bps - rd.throughput_bps) \
                < 0.15 * rd.throughput_bps

    def test_dma_is_13gbs_825pct_of_pcie(self):
        thr = simulate_dma(1 << 26)
        assert abs(thr - 13.0e9) < 0.4e9
        assert abs(thr / PAPER_HW.pcie_peak - 0.825) < 0.02

    def test_host_access_latency_fig8(self):
        assert abs(simulate_host_access(64) - 600e-9) < 60e-9
        assert abs(simulate_host_access(2048) - 964e-9) < 96e-9
        # monotone in message size
        lats = [simulate_host_access(n) for n in (64, 256, 1024, 2048,
                                                  8192)]
        assert lats == sorted(lats)


class TestSimulatorProperties:
    @settings(max_examples=40, deadline=None)
    @given(payload=st.integers(64, 1 << 20), batch=st.integers(1, 200))
    def test_throughput_below_line_rate(self, payload, batch):
        r = simulate_rdma("read", payload, batch)
        assert r.throughput_bps <= PAPER_HW.line_rate * 8 + 1e-9

    @settings(max_examples=40, deadline=None)
    @given(payload=st.integers(64, 1 << 18))
    def test_batching_never_hurts(self, payload):
        single = simulate_rdma("read", payload, 1)
        batch = simulate_rdma("read", payload, 50)
        assert batch.throughput_bps >= single.throughput_bps

    @settings(max_examples=20, deadline=None)
    @given(payload=st.integers(64, 1 << 16), batch=st.integers(1, 100))
    def test_dev_mem_qp_no_slower(self, payload, batch):
        host = simulate_rdma("read", payload, batch, "host_mem")
        dev = simulate_rdma("read", payload, batch, "dev_mem")
        assert dev.total_time <= host.total_time + 1e-12


class TestGoldenFairness:
    """Golden-trace fairness: the checked-in fair_* testcases pin the
    multi-QP scheduler's per-QP service shares and completion spreads
    (the traces drive the production schedule_plan, not a model copy)."""

    def _run(self, name):
        out = run_testcase(os.path.join(TESTCASE_DIR, name))
        assert out["pass"], f"{name}: {out['checks']}"
        return out

    def test_fair_2qp_interleave_trace(self):
        out = self._run("fair_2qp_interleave.json")
        # even split of the first contended flush, perfectly fair
        assert out["first_flush_shares"] == [0.5, 0.5]
        assert out["jain_index"] == 1.0
        # the shallow QP completes in the very first flush
        assert out["completion_us"][1] < out["completion_us"][0]

    def test_fair_weighted_4qp_trace(self):
        out = self._run("fair_weighted_4qp.json")
        # weight-3 QP earns exactly half of the 12-WQE budget; the three
        # weight-1 QPs split the rest evenly
        shares = out["first_flush_shares"]
        assert shares[0] == pytest.approx(0.5)
        assert shares[1:] == pytest.approx([1 / 6] * 3)
        assert out["jain_index"] == pytest.approx(
            jain_fairness_index([6, 2, 2, 2]))

    def test_rr_dominates_fifo_on_fairness(self):
        """Same contention, scheduler flipped: FIFO starves the first
        flush (one QP takes the whole budget) while RR splits it."""
        depths, budget = [64, 8, 8, 8], 16
        rr = simulate_fair_schedule(depths, "rr", budget=budget)
        ff = simulate_fair_schedule(depths, "fifo", budget=budget)
        assert min(ff["first_flush_shares"]) == 0.0      # starvation
        assert min(rr["first_flush_shares"]) == pytest.approx(0.25)
        assert rr["jain_index"] > ff["jain_index"]
        # shallow QPs finish strictly earlier under RR
        assert max(rr["completion_us"][1:]) < min(ff["completion_us"][1:])


    def test_drr_trace_weight3_lc_stream_vs_hosts(self):
        """lc_host_contention golden trace: a weight-3 LC kernel stream
        (deep QP0) sharing the engine with three host QPs under drr — the
        LC stream earns exactly half of each 12-WQE budget, the hosts
        split the rest evenly, and nobody starves."""
        out = self._run("lc_host_contention.json")
        shares = out["first_flush_shares"]
        assert shares[0] == pytest.approx(0.5)
        assert shares[1:] == pytest.approx([1 / 6] * 3)
        # host QPs all finish together, well before the deep LC stream
        assert max(out["completion_us"][1:]) < out["completion_us"][0]
        assert (out["completion_us"][1]
                == pytest.approx(out["completion_us"][3]))

    def test_drr_repays_budget_truncated_service(self):
        """Carry-over in action: weights [5,1] with budget 3 truncate
        QP0's 5-WQE quantum every flush. drr banks the cut and repays it,
        holding the exact 5:1 service ratio throughout — with depths
        60:12 (= 5:1) the two QPs drain in lockstep and finish in the
        same flush. Plain rr never repays (the quantum is re-capped at 3
        each flush), so the weight-5 QP monopolizes whole flushes and
        drains strictly earlier while the weight-1 QP waits."""
        drr = simulate_fair_schedule([60, 12], scheduler="drr",
                                     weights=[5, 1], budget=3)
        rr = simulate_fair_schedule([60, 12], scheduler="rr",
                                    weights=[5, 1], budget=3)
        assert drr["completion_us"][0] == pytest.approx(
            drr["completion_us"][1])
        assert drr["completion_us"][0] == pytest.approx(drr["makespan_us"])
        assert rr["completion_us"][0] < rr["completion_us"][1]

    def test_lc_offload_mm_trace(self):
        """lc_offload_mm golden trace: the offloaded skinny matmul beats
        host staging (data movement dominates) and moves exactly half
        the bytes — the paper's whole argument for on-NIC compute."""
        out = self._run("lc_offload_mm.json")
        assert out["offload_speedup"] > 1.25
        assert out["bytes_moved_ratio"] == pytest.approx(2.0)
        assert out["offload_pcie_bytes"] == 0.0

    def test_degenerate_inputs(self):
        with pytest.raises(ValueError):
            simulate_fair_schedule([4, 4], budget=0)
        out = simulate_fair_schedule([0, 0])
        assert out["flushes"] == 0
        assert out["first_flush_shares"] == [0.0, 0.0]

    def test_unknown_golden_key_fails_cleanly(self):
        """A typo'd / op-mismatched golden key is a failed check, not a
        KeyError aborting the run."""
        out = run_testcase({"op": "fair_schedule", "qp_depths": [4, 4],
                            "golden": {"throughput_gbps": 1.0,
                                       "rtol": 0.1}})
        assert not out["pass"]
        assert out["checks"] == [("throughput_gbps", 1.0, None, False)]


def test_json_testcases_regression():
    """run_testcase over the checked-in testcases (paper §V analogue)."""
    cases = sorted(glob.glob(os.path.join(TESTCASE_DIR, "*.json")))
    assert len(cases) >= 8
    for path in cases:
        out = run_testcase(path)
        assert out["pass"], f"{path}: {out['checks']}"
