"""Lookaside offload conformance: LC kernels as first-class clients of the
shared engine.

Contracts pinned here:

* each registered offload kernel's RDMA-read -> compute -> RDMA-write
  result is BYTE-identical to the host-side oracle in ``kernels/ref.py``,
  on both transports (LocalTransport here, ICITransport in a forced
  multi-device subprocess);
* LC WQEs land in the SAME descriptor table as concurrent host verbs
  traffic (``interleaved_batches``; ``qp_service``/``lc_service``);
* StatusMsg completion is CQE-driven: with a deferred write-back the
  status appears only after a (host-driven) flush executes the write-back
  WQE — in poll AND interrupt mode;
* engine-level failures (bad rkey) surface as ``StatusMsg(ok=False)``,
  control-FIFO overflow as a *retryable* ``StatusMsg(ok=False)`` — no
  RuntimeError unwinds the engine loop (the FIFO backpressure fix);
* LC contention terms flow through ``predict_from_stats``.
"""
import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.lookaside import ControlMsg, FIFO, LookasideBlock
from repro.core.rdma import Opcode, RDMAEngine, WQE
from repro.kernels import ref
from repro.kernels.lc_offload import (MM_WORKLOAD, PARSER_WORKLOAD,
                                      register_default_kernels)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")

RNG = np.random.default_rng(7)
DATA_PEER = 1            # remote peer holding operands/results
LC_PEER = 0              # the NIC the LC block rides


def _engine(**kw):
    kw.setdefault("n_peers", 2)
    kw.setdefault("pool_size", 1 << 14)
    eng = RDMAEngine(**kw)
    blk = LookasideBlock(eng, peer=LC_PEER, scratch_base=1 << 13)
    register_default_kernels(blk)
    return eng, blk


def _place_mm(eng, m, k, n):
    A = RNG.standard_normal((m, k)).astype(np.float32)
    B = RNG.standard_normal((k, n)).astype(np.float32)
    a_addr, b_addr = 0, m * k
    out_addr = m * k + k * n
    mr = eng.register_mr(DATA_PEER, 0, out_addr + m * n)
    eng.write_buffer(DATA_PEER, a_addr, A.ravel())
    eng.write_buffer(DATA_PEER, b_addr, B.ravel())
    return A, B, mr, (a_addr, b_addr, out_addr)


def _roce_packets(n_pkts):
    pkts = RNG.integers(0, 256, size=(n_pkts, 64)).astype(np.uint8)
    pkts[::2, 12:14] = [0x08, 0x00]      # IPv4
    pkts[::2, 23] = 17                   # UDP
    pkts[::2, 36:38] = [18, 183]         # dport 4791 (RoCEv2)
    return pkts


class TestOffloadParity:
    @pytest.mark.parametrize("m,k,n", [(8, 16, 12), (16, 32, 8),
                                       (4, 128, 4)])
    def test_systolic_mm_byte_identical_to_host_reference(self, m, k, n):
        eng, blk = _engine()
        A, B, mr, (a, b, out) = _place_mm(eng, m, k, n)
        assert blk.dispatch(ControlMsg(
            MM_WORKLOAD, (DATA_PEER, mr.rkey, a, b, out, m, k, n),
            tag=3)) is None
        st = blk.poll(MM_WORKLOAD)
        assert st is not None and st.ok and st.tag == 3
        assert st.result_addr == out
        got = eng.read_buffer(DATA_PEER, out, m * n).reshape(m, n)
        want = np.asarray(ref.ref_matmul(jnp.asarray(A), jnp.asarray(B)))
        np.testing.assert_array_equal(got, want)      # byte-identical

    def test_packet_parser_byte_identical_to_host_reference(self):
        eng, blk = _engine()
        n_pkts = 32
        pkts = _roce_packets(n_pkts)
        p_addr, out_addr = 0, n_pkts * 64
        mr = eng.register_mr(DATA_PEER, 0, n_pkts * 64 + n_pkts * 4)
        eng.write_buffer(DATA_PEER, p_addr, pkts.astype(np.float32).ravel())
        blk.dispatch(ControlMsg(
            PARSER_WORKLOAD, (DATA_PEER, mr.rkey, p_addr, n_pkts, out_addr),
            tag=4))
        st = blk.poll(PARSER_WORKLOAD)
        assert st is not None and st.ok
        got = eng.read_buffer(DATA_PEER, out_addr, n_pkts * 4
                              ).reshape(n_pkts, 4)
        want = np.asarray(ref.ref_parse_packets(jnp.asarray(pkts)))
        np.testing.assert_array_equal(got, want)

    @pytest.mark.slow
    def test_offload_parity_on_ici_transport(self):
        """Both kernels on the real collective transport (forced 2-device
        mesh): byte-identical to the oracles."""
        code = """
import numpy as np
import jax.numpy as jnp
from repro.core.rdma import RDMAEngine
from repro.core.rdma.transport import ICITransport
from repro.core.lookaside import ControlMsg, LookasideBlock
from repro.kernels import ref
from repro.kernels.lc_offload import (MM_WORKLOAD, PARSER_WORKLOAD,
                                      register_default_kernels)

eng = RDMAEngine(n_peers=2, pool_size=1 << 14)
assert isinstance(eng.transport, ICITransport), type(eng.transport)
blk = LookasideBlock(eng, peer=0, scratch_base=1 << 13)
register_default_kernels(blk)
rng = np.random.default_rng(11)

m, k, n = 8, 16, 12
A = rng.standard_normal((m, k)).astype(np.float32)
B = rng.standard_normal((k, n)).astype(np.float32)
mr = eng.register_mr(1, 0, 4096)
eng.write_buffer(1, 0, A.ravel())
eng.write_buffer(1, m * k, B.ravel())
out = m * k + k * n
blk.dispatch(ControlMsg(MM_WORKLOAD, (1, mr.rkey, 0, m * k, out, m, k, n)))
st = blk.poll(MM_WORKLOAD)
assert st is not None and st.ok, st
got = eng.read_buffer(1, out, m * n).reshape(m, n)
want = np.asarray(ref.ref_matmul(jnp.asarray(A), jnp.asarray(B)))
np.testing.assert_array_equal(got, want)

n_pkts = 16
pkts = rng.integers(0, 256, size=(n_pkts, 64)).astype(np.uint8)
pkts[::2, 12:14] = [8, 0]; pkts[::2, 23] = 17; pkts[::2, 36:38] = [18, 183]
base = 2048
mr2 = eng.register_mr(1, base, n_pkts * 68)
eng.write_buffer(1, base, pkts.astype(np.float32).ravel())
blk.dispatch(ControlMsg(
    PARSER_WORKLOAD, (1, mr2.rkey, base, n_pkts, base + n_pkts * 64)))
st = blk.poll(PARSER_WORKLOAD)
assert st is not None and st.ok, st
got = eng.read_buffer(1, base + n_pkts * 64, n_pkts * 4).reshape(n_pkts, 4)
np.testing.assert_array_equal(
    got, np.asarray(ref.ref_parse_packets(jnp.asarray(pkts))))
print("ICI_LC_OK", eng.stats["lc_wqes"])
"""
        env = dict(os.environ)
        env["PYTHONPATH"] = SRC
        env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
        r = subprocess.run([sys.executable, "-c", code], env=env,
                           capture_output=True, text=True, timeout=560)
        assert "ICI_LC_OK" in r.stdout, r.stdout + r.stderr


class TestSharedEngineContention:
    def test_lc_wqes_share_descriptor_table_with_host_traffic(self):
        """The acceptance criterion: one LC invocation's WQEs are
        scheduled into the same flush as concurrent host verbs traffic —
        interleaved_batches fires and both parties appear in the service
        ledger (LC QPs also in lc_service)."""
        eng, blk = _engine(scheduler="drr", flush_budget=8)
        A, B, mr, (a, b, out) = _place_mm(eng, 8, 16, 8)
        hqp = eng.create_qp(LC_PEER, DATA_PEER)
        for i in range(6):
            eng.post_send(hqp, WQE(
                Opcode.READ, hqp.qp_num, wr_id=i, local_addr=6000 + i,
                remote_addr=i, length=1, rkey=mr.rkey))
        eng.ring_sq_doorbell(hqp, defer=True)      # host armed, not flushed
        i0 = eng.stats["transport"]["interleaved_batches"]

        blk.dispatch(ControlMsg(
            MM_WORKLOAD, (DATA_PEER, mr.rkey, a, b, out, 8, 16, 8), tag=1))
        assert blk.poll(MM_WORKLOAD).ok
        assert eng.stats["transport"]["interleaved_batches"] > i0
        lc_qp = blk.kernels[MM_WORKLOAD].qps[DATA_PEER]
        assert eng.stats["qp_service"][hqp.qp_num] > 0
        assert eng.stats["qp_service"][lc_qp.qp_num] == 3   # 2 READ + 1 WRITE
        assert eng.stats["lc_service"] == {lc_qp.qp_num: 3}
        assert eng.stats["lc_wqes"] == 3
        # latency histogram ledger covers every serviced WQE
        for q in (hqp.qp_num, lc_qp.qp_num):
            assert (sum(eng.stats["qp_latency_us"][q].values())
                    == eng.stats["qp_service"][q])
        while hqp.pending():
            eng.flush_doorbells()
        assert [c.wr_id for c in eng.poll_cq(hqp, 64)] == list(range(6))

    def test_predict_from_stats_carries_lc_contention_terms(self):
        from repro.core.rdma.simulator import predict_from_stats
        eng, blk = _engine(scheduler="drr", flush_budget=8)
        A, B, mr, (a, b, out) = _place_mm(eng, 8, 16, 8)
        hqp = eng.create_qp(LC_PEER, DATA_PEER)
        for i in range(5):
            eng.post_send(hqp, WQE(
                Opcode.READ, hqp.qp_num, wr_id=i, local_addr=6000 + i,
                remote_addr=i, length=1, rkey=mr.rkey))
        eng.ring_sq_doorbell(hqp, defer=True)
        blk.dispatch(ControlMsg(
            MM_WORKLOAD, (DATA_PEER, mr.rkey, a, b, out, 8, 16, 8), tag=1))
        while hqp.pending():
            eng.flush_doorbells()
        m = predict_from_stats(eng.stats, payload=4096, op="read")
        assert m["lc_wqes"] == 3.0
        assert 0.0 < m["lc_share"] < 1.0
        assert m["lc_contention_s"] > 0.0
        assert m["host_jain_index"] == 1.0       # single host QP
        assert m["host_slowdown_from_lc"] > 1.0
        # byte ledger: LC moved A+B+C, host moved its 5 single-word reads
        lc_qp = blk.kernels[MM_WORKLOAD].qps[DATA_PEER]
        assert eng.stats["qp_bytes"][lc_qp.qp_num] == 8 * 16 + 16 * 8 + 8 * 8
        assert eng.stats["qp_bytes"][hqp.qp_num] == 5


class TestCQEDrivenStatus:
    def test_statusmsg_appears_only_after_writeback_cqe_poll_mode(self):
        eng, blk = _engine()
        blk.eager_writeback = False       # leave the write-back armed
        A, B, mr, (a, b, out) = _place_mm(eng, 8, 16, 8)
        blk.dispatch(ControlMsg(
            MM_WORKLOAD, (DATA_PEER, mr.rkey, a, b, out, 8, 16, 8), tag=2))
        # kernel fn is done, but the write-back WQE has not executed:
        # no StatusMsg yet, and the remote result region is still zeros
        assert blk.poll(MM_WORKLOAD) is None
        assert not np.any(eng.read_buffer(DATA_PEER, out, 8 * 8))
        eng.flush_doorbells()             # a HOST-driven flush completes it
        st = blk.poll(MM_WORKLOAD)
        assert st is not None and st.ok and st.tag == 2
        got = eng.read_buffer(DATA_PEER, out, 8 * 8).reshape(8, 8)
        np.testing.assert_array_equal(
            got, np.asarray(ref.ref_matmul(jnp.asarray(A), jnp.asarray(B))))

    def test_statusmsg_interrupt_mode_fires_on_cqe(self):
        eng, blk = _engine()
        blk.eager_writeback = False
        seen = []
        blk.register_interrupt(MM_WORKLOAD, seen.append)
        A, B, mr, (a, b, out) = _place_mm(eng, 8, 16, 8)
        blk.dispatch(ControlMsg(
            MM_WORKLOAD, (DATA_PEER, mr.rkey, a, b, out, 8, 16, 8), tag=6))
        assert seen == []                 # not before the write-back CQE
        eng.flush_doorbells()
        assert len(seen) == 1 and seen[0].ok and seen[0].tag == 6

    def test_engine_failure_surfaces_as_not_ok_status(self):
        eng, blk = _engine()
        A, B, mr, (a, b, out) = _place_mm(eng, 8, 16, 8)
        blk.dispatch(ControlMsg(
            MM_WORKLOAD, (DATA_PEER, 0xBAD, a, b, out, 8, 16, 8), tag=8))
        st = blk.poll(MM_WORKLOAD)
        assert st is not None and not st.ok and not st.retryable
        assert "remote_access_error" in st.detail
        assert blk.stats["errors"] == 1


class TestFIFOBackpressure:
    def test_dispatch_backpressure_is_retryable_status_not_raise(self):
        """Regression for the FIFO.push RuntimeError: a full control FIFO
        must surface as a retryable StatusMsg(ok=False) — the engine loop
        never sees an exception — and the same message dispatches fine
        after the queue drains."""
        eng, blk = _engine()
        k = blk.kernels[MM_WORKLOAD]
        k.control_fifo = FIFO(depth=2)
        A, B, mr, (a, b, out) = _place_mm(eng, 8, 16, 8)
        args = (DATA_PEER, mr.rkey, a, b, out, 8, 16, 8)
        # fabric busy: enqueue without servicing until the FIFO fills
        assert blk.dispatch(ControlMsg(MM_WORKLOAD, args, tag=1),
                            service=False) is None
        assert blk.dispatch(ControlMsg(MM_WORKLOAD, args, tag=2),
                            service=False) is None
        st = blk.dispatch(ControlMsg(MM_WORKLOAD, args, tag=3),
                          service=False)
        assert st is not None and not st.ok and st.retryable
        assert st.tag == 3 and "backpressure" in st.detail
        assert blk.stats["backpressure"] == 1
        blk.service(MM_WORKLOAD)          # fabric drains the queue
        assert blk.poll(MM_WORKLOAD).tag == 1
        assert blk.poll(MM_WORKLOAD).tag == 2
        # the rejected message retries cleanly
        assert blk.dispatch(ControlMsg(MM_WORKLOAD, args, tag=3)) is None
        assert blk.poll(MM_WORKLOAD).tag == 3

    def test_raw_fifo_push_still_raises_try_push_does_not(self):
        f = FIFO(depth=1)
        assert f.try_push(1)
        assert not f.try_push(2)          # backpressure, no raise
        with pytest.raises(RuntimeError, match="backpressure"):
            f.push(3)
        assert len(f) == 1
