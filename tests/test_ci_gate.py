"""CI baseline gate unit tests: the schema-driven regression checks in
``scripts/ci_gate.py`` must catch injected regressions with per-key
messages, honor directions/tolerances, and support baseline updates —
WITHOUT running any benchmark (the rule engine is pure)."""
import json
import os
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "scripts"))

import ci_gate  # noqa: E402
from ci_gate import Gate, Rule, check_gate, check_rule, lookup  # noqa: E402


class TestLookup:
    def test_dotted_path(self):
        rec = {"a": {"b": {"c": 3}}, "x": 1}
        assert lookup(rec, "a.b.c") == 3
        assert lookup(rec, "x") == 1
        assert lookup(rec, "a.missing") is None
        assert lookup(rec, "x.deeper") is None


class TestRuleDirections:
    def test_lower_is_better_fails_on_increase(self):
        r = Rule("compiles", "<=")
        assert check_rule(r, {"compiles": 4}, {"compiles": 4}) is None
        msg = check_rule(r, {"compiles": 5}, {"compiles": 4})
        assert msg is not None and "compiles" in msg and "5" in msg

    def test_lower_is_better_tolerance(self):
        r = Rule("wall", "<=", tolerance=0.5)
        assert check_rule(r, {"wall": 1.4}, {"wall": 1.0}) is None
        assert check_rule(r, {"wall": 1.6}, {"wall": 1.0}) is not None

    def test_higher_is_better_fails_on_decrease(self):
        r = Rule("jain", ">=", tolerance=0.02)
        assert check_rule(r, {"jain": 0.99}, {"jain": 1.0}) is None
        msg = check_rule(r, {"jain": 0.9}, {"jain": 1.0})
        assert msg is not None and "jain" in msg

    def test_exact_match_and_bools(self):
        r = Rule("ratio", "==", tolerance=0.0)
        assert check_rule(r, {"ratio": 2.0}, {"ratio": 2.0}) is None
        assert check_rule(r, {"ratio": 2.1}, {"ratio": 2.0}) is not None
        rb = Rule("parity", "==")
        assert check_rule(rb, {"parity": True}, {"parity": True}) is None
        assert check_rule(rb, {"parity": False},
                          {"parity": True}) is not None

    def test_key_missing_from_baseline_is_skipped(self):
        # older baselines predate new keys: not a failure
        assert check_rule(Rule("new_key", "<="), {"new_key": 9}, {}) is None

    def test_key_missing_from_record_is_a_regression(self):
        msg = check_rule(Rule("gone", "<="), {}, {"gone": 1})
        assert msg is not None and "missing" in msg

    def test_unknown_direction_raises(self):
        with pytest.raises(ValueError, match="direction"):
            check_rule(Rule("k", "!!"), {"k": 1}, {"k": 1})


class TestInjectedRegression:
    GATE = Gate("demo", "BENCH_demo.json", "BENCH_demo.ci.json",
                rules=(Rule("descriptor_compiles", "<="),
                       Rule("nested.jain", ">=", 0.02)))

    def test_clean_record_passes(self):
        base = {"descriptor_compiles": 2, "nested": {"jain": 1.0}}
        assert check_gate(self.GATE, dict(base), base) == []

    def test_injected_compile_regression_fails_with_named_key(self):
        base = {"descriptor_compiles": 2, "nested": {"jain": 1.0}}
        rec = {"descriptor_compiles": 7, "nested": {"jain": 1.0}}
        msgs = check_gate(self.GATE, rec, base)
        assert len(msgs) == 1
        assert "demo.descriptor_compiles" in msgs[0] and "7" in msgs[0]

    def test_multiple_regressions_all_reported(self):
        base = {"descriptor_compiles": 2, "nested": {"jain": 1.0}}
        rec = {"descriptor_compiles": 3, "nested": {"jain": 0.5}}
        msgs = check_gate(self.GATE, rec, base)
        assert len(msgs) == 2

    def test_committed_schema_gates_all_benches(self):
        """The live schema must cover every committed BENCH baseline,
        with the compile-count keys gated at zero tolerance. The
        roofline gate is the ONE exemption from the compile-rule
        requirement: it gates the dry-run-artifact table generator's
        health flags, not a transport path with a compile cache."""
        names = {g.baseline for g in ci_gate.GATES}
        assert names == {"BENCH_transport.json", "BENCH_fairness.json",
                         "BENCH_lc_offload.json", "BENCH_streaming.json",
                         "BENCH_dispatch.json", "BENCH_reliability.json",
                         "BENCH_kv_serve.json", "BENCH_collectives.json",
                         "BENCH_chains.json", "BENCH_autotune.json",
                         "BENCH_roofline.json"}
        exempt = {g.name for g in ci_gate.GATES
                  if not any("compile" in r.key for r in g.rules)}
        assert exempt == {"roofline"}
        for g in ci_gate.GATES:
            compile_rules = [r for r in g.rules if "compile" in r.key]
            assert all(r.direction == "<=" and r.tolerance == 0.0
                       for r in compile_rules)
            assert g.runner is not None

    def test_dispatch_gate_pins_parity_and_flush_keys(self):
        """The dispatch gate's scale-invariant schema: steady-state
        compile counts at zero tolerance, per-class byte parity exact,
        flush merging + PR-4 one-entry parity — and injecting a
        regression into each key fails on exactly that key."""
        g = next(g for g in ci_gate.GATES if g.name == "dispatch")
        keys = {r.key for r in g.rules}
        assert {"warm_descriptor_compiles", "warm_qdma_compiles",
                "parser_parity", "quant_parity",
                "flush_ratio_split_over_mixed",
                "pr4_flush_parity"} <= keys
        parity = next(r for r in g.rules if r.key == "pr4_flush_parity")
        assert parity.direction == "==" and parity.tolerance == 0.0
        base = {"warm_descriptor_compiles": 0, "warm_qdma_compiles": 0,
                "parser_parity": True, "quant_parity": True,
                "flush_ratio_split_over_mixed": 1.33,
                "pr4_flush_parity": 1.0}
        assert check_gate(g, dict(base), base) == []
        for key, bad in (("warm_descriptor_compiles", 2),
                         ("parser_parity", False),
                         ("quant_parity", False),
                         ("flush_ratio_split_over_mixed", 0.9),
                         ("pr4_flush_parity", 1.5)):
            rec = dict(base, **{key: bad})
            msgs = check_gate(g, rec, base)
            assert len(msgs) == 1 and key in msgs[0], (key, msgs)

    def test_reliability_gate_pins_chaos_smoke_keys(self):
        """The reliability gate's schema: zero-tolerance retransmit-path
        compile count, byte parity + CQE order under 10% drop, bounded
        retransmission overhead, innocent-QP fairness, and the terminal
        CQE / recovery contract — injecting a regression into each key
        fails on exactly that key."""
        g = next(g for g in ci_gate.GATES if g.name == "reliability")
        keys = {r.key for r in g.rules}
        assert {"warm_descriptor_compiles", "parity_10pct_drop",
                "cqe_order_ok", "flush_overhead_ratio",
                "fairness.host_jain_while_victim_retx",
                "recovery.terminal_cqes_not_exceptions",
                "recovery.recovered_ok"} <= keys
        compiles = next(r for r in g.rules
                        if r.key == "warm_descriptor_compiles")
        assert compiles.direction == "<=" and compiles.tolerance == 0.0
        base = {"warm_descriptor_compiles": 0, "parity_10pct_drop": True,
                "cqe_order_ok": True, "flush_overhead_ratio": 1.6,
                "fairness": {"host_jain_while_victim_retx": 1.0},
                "recovery": {"terminal_cqes_not_exceptions": True,
                             "recovered_ok": True}}
        assert check_gate(g, json.loads(json.dumps(base)), base) == []
        for key, bad in (
                ("warm_descriptor_compiles", 2),
                ("parity_10pct_drop", False),
                ("cqe_order_ok", False),
                ("flush_overhead_ratio", 3.5),
                ("fairness.host_jain_while_victim_retx", 0.4),
                ("recovery.terminal_cqes_not_exceptions", False),
                ("recovery.recovered_ok", False)):
            rec = json.loads(json.dumps(base))
            node = rec
            *parents, leaf = key.split(".")
            for p in parents:
                node = node[p]
            node[leaf] = bad
            msgs = check_gate(g, rec, base)
            assert len(msgs) == 1 and key in msgs[0], (key, msgs)

    def test_kv_serve_gate_pins_serving_keys(self):
        """The kv_serve gate's schema: zero-tolerance steady-state
        compile counts, the exact 2.0x host-staging bytes ratio, fetch
        and compression parity, the adversary-proof innocent Jain, and
        the migration no-loss/ledger/error-path contract — injecting a
        regression into each key fails on exactly that key."""
        g = next(g for g in ci_gate.GATES if g.name == "kv_serve")
        keys = {r.key for r in g.rules}
        assert {"warm_descriptor_compiles", "warm_qdma_compiles",
                "bytes_moved_ratio", "fetch_parity",
                "compression.wire_ratio", "compression.parity",
                "open_loop.innocent_jain", "open_loop.no_pages_lost",
                "migration.no_pages_lost", "migration.ledger_conserved",
                "migration.error_path.src_intact"} <= keys
        ratio = next(r for r in g.rules if r.key == "bytes_moved_ratio")
        assert ratio.direction == "==" and ratio.tolerance == 0.0
        base = {"warm_descriptor_compiles": 0, "warm_qdma_compiles": 0,
                "bytes_moved_ratio": 2.0, "fetch_parity": True,
                "compression": {"wire_ratio": 1.939, "parity": True},
                "open_loop": {"innocent_jain": 1.0,
                              "no_pages_lost": True},
                "migration": {"no_pages_lost": True,
                              "ledger_conserved": True,
                              "error_path": {"src_intact": True}}}
        assert check_gate(g, json.loads(json.dumps(base)), base) == []
        for key, bad in (
                ("warm_descriptor_compiles", 2),
                ("warm_qdma_compiles", 1),
                ("bytes_moved_ratio", 1.0),
                ("fetch_parity", False),
                ("compression.wire_ratio", 1.0),
                ("compression.parity", False),
                ("open_loop.innocent_jain", 0.9),
                ("open_loop.no_pages_lost", False),
                ("migration.no_pages_lost", False),
                ("migration.ledger_conserved", False),
                ("migration.error_path.src_intact", False)):
            rec = json.loads(json.dumps(base))
            node = rec
            *parents, leaf = key.split(".")
            for p in parents:
                node = node[p]
            node[leaf] = bad
            msgs = check_gate(g, rec, base)
            assert len(msgs) == 1 and key in msgs[0], (key, msgs)

    def test_collectives_gate_pins_training_keys(self):
        """The collectives gate's schema: zero-tolerance steady-state
        compile counts, the exact ring wire-words ratio vs the α–β
        ideal, both algorithms' oracle parity, a real overlap fraction,
        the serving-tenant Jain floor, and chaos parity — injecting a
        regression into each key fails on exactly that key."""
        g = next(g for g in ci_gate.GATES if g.name == "collectives")
        keys = {r.key for r in g.rules}
        assert {"warm_descriptor_compiles", "warm_qdma_compiles",
                "ring.wire_ratio", "ring.parity", "rd.parity",
                "overlap.overlap_fraction", "fairness.serving_jain",
                "chaos.parity_10pct_drop"} <= keys
        for key in ("warm_descriptor_compiles", "warm_qdma_compiles"):
            rule = next(r for r in g.rules if r.key == key)
            assert rule.direction == "<=" and rule.tolerance == 0.0
        base = {"warm_descriptor_compiles": 0, "warm_qdma_compiles": 0,
                "ring": {"wire_ratio": 1.0, "parity": True},
                "rd": {"parity": True},
                "overlap": {"overlap_fraction": 1.0},
                "fairness": {"serving_jain": 1.0},
                "chaos": {"parity_10pct_drop": True}}
        assert check_gate(g, json.loads(json.dumps(base)), base) == []
        for key, bad in (
                ("warm_descriptor_compiles", 1),
                ("warm_qdma_compiles", 3),
                ("ring.wire_ratio", 1.5),
                ("ring.parity", False),
                ("rd.parity", False),
                ("overlap.overlap_fraction", 0.0),
                ("fairness.serving_jain", 0.66),
                ("chaos.parity_10pct_drop", False)):
            rec = json.loads(json.dumps(base))
            node = rec
            *parents, leaf = key.split(".")
            for p in parents:
                node = node[p]
            node[leaf] = bad
            msgs = check_gate(g, rec, base)
            assert len(msgs) == 1 and key in msgs[0], (key, msgs)

    def test_chains_gate_pins_pipeline_keys(self):
        """The chains gate's schema: zero-tolerance steady-state compile
        counts, stage/egress byte parity + checksum stamps, the shared
        inter-stage flush win, exact chain completion, chaos parity with
        a zero-compile retransmit path, and the model's chained win —
        injecting a regression into each key fails on exactly that key."""
        g = next(g for g in ci_gate.GATES if g.name == "chains")
        keys = {r.key for r in g.rules}
        assert {"warm_descriptor_compiles", "warm_qdma_compiles",
                "stage_parity", "egress_parity", "checksums_ok",
                "flush_ratio_staged_over_chained", "chain_completion",
                "chaos.parity_10pct_drop",
                "chaos.warm_descriptor_compiles",
                "model.flush_ratio",
                "model.chained_speedup_vs_staged"} <= keys
        for key in ("warm_descriptor_compiles", "warm_qdma_compiles",
                    "chaos.warm_descriptor_compiles"):
            rule = next(r for r in g.rules if r.key == key)
            assert rule.direction == "<=" and rule.tolerance == 0.0
        completion = next(r for r in g.rules if r.key == "chain_completion")
        assert completion.direction == "==" and completion.tolerance == 0.0
        base = {"warm_descriptor_compiles": 0, "warm_qdma_compiles": 0,
                "stage_parity": True, "egress_parity": True,
                "checksums_ok": True,
                "flush_ratio_staged_over_chained": 1.2,
                "chain_completion": 1.0,
                "chaos": {"parity_10pct_drop": True,
                          "warm_descriptor_compiles": 0},
                "model": {"flush_ratio": 1.83,
                          "chained_speedup_vs_staged": 1.61}}
        assert check_gate(g, json.loads(json.dumps(base)), base) == []
        for key, bad in (
                ("warm_descriptor_compiles", 2),
                ("warm_qdma_compiles", 1),
                ("stage_parity", False),
                ("egress_parity", False),
                ("checksums_ok", False),
                ("flush_ratio_staged_over_chained", 0.9),
                ("chain_completion", 0.8),
                ("chaos.parity_10pct_drop", False),
                ("chaos.warm_descriptor_compiles", 4),
                ("model.flush_ratio", 0.5),
                ("model.chained_speedup_vs_staged", 0.5)):
            rec = json.loads(json.dumps(base))
            node = rec
            *parents, leaf = key.split(".")
            for p in parents:
                node = node[p]
            node[leaf] = bad
            msgs = check_gate(g, rec, base)
            assert len(msgs) == 1 and key in msgs[0], (key, msgs)

    def test_autotune_gate_pins_self_tuning_keys(self):
        """The autotune gate's schema: the learner keeps prewarm at zero
        cold-start misses / steady-state compiles / widened-shift
        misses, the seeded sweep stays deterministic with warm trials,
        and the tuned point never drops below the hand-picked defaults
        — injecting a regression into each key fails on exactly that
        key."""
        g = next(g for g in ci_gate.GATES if g.name == "autotune")
        keys = {r.key for r in g.rules}
        assert {"learner.learned_prewarm_misses",
                "learner.steady_state_compiles",
                "learner.widened_shift_misses",
                "learner.prewarm_parity",
                "tuner.sweep_deterministic",
                "tuner.warm_descriptor_compiles",
                "tuner.tuned_at_least_default",
                "tuner.improvement"} <= keys
        for key in ("learner.steady_state_compiles",
                    "tuner.warm_descriptor_compiles"):
            rule = next(r for r in g.rules if r.key == key)
            assert rule.direction == "<=" and rule.tolerance == 0.0
        base = {"learner": {"learned_prewarm_misses": 0,
                            "steady_state_compiles": 0,
                            "widened_shift_misses": 0,
                            "prewarm_parity": True},
                "tuner": {"sweep_deterministic": True,
                          "warm_descriptor_compiles": 0,
                          "tuned_at_least_default": True,
                          "improvement": 2.36}}
        assert check_gate(g, json.loads(json.dumps(base)), base) == []
        for key, bad in (
                ("learner.learned_prewarm_misses", 2),
                ("learner.steady_state_compiles", 1),
                ("learner.widened_shift_misses", 3),
                ("learner.prewarm_parity", False),
                ("tuner.sweep_deterministic", False),
                ("tuner.warm_descriptor_compiles", 4),
                ("tuner.tuned_at_least_default", False),
                ("tuner.improvement", 1.0)):
            rec = json.loads(json.dumps(base))
            node = rec
            *parents, leaf = key.split(".")
            for p in parents:
                node = node[p]
            node[leaf] = bad
            msgs = check_gate(g, rec, base)
            assert len(msgs) == 1 and key in msgs[0], (key, msgs)

    def test_roofline_gate_health_flags_and_artifact_direction(self):
        """The roofline gate's schema: ran_ok exact; has_artifacts gated
        ">=" so a runner WITHOUT dry-run artifacts passes against a
        False baseline and may flip to True, but a baseline recorded
        WITH artifacts fails if they vanish; the ratio floors only bind
        when the baseline carries them."""
        g = next(g for g in ci_gate.GATES if g.name == "roofline")
        keys = {r.key for r in g.rules}
        assert {"ran_ok", "has_artifacts", "min_useful_ratio",
                "max_roofline_fraction"} <= keys
        no_art = {"ran_ok": True, "has_artifacts": False, "cells": 0}
        assert check_gate(g, dict(no_art), no_art) == []
        # artifacts appearing later is an improvement, not a regression
        assert check_gate(g, dict(no_art, has_artifacts=True,
                                  cells=4), no_art) == []
        with_art = {"ran_ok": True, "has_artifacts": True, "cells": 4,
                    "min_useful_ratio": 0.8,
                    "max_roofline_fraction": 0.5}
        assert check_gate(g, json.loads(json.dumps(with_art)),
                          with_art) == []
        for key, bad in (("ran_ok", False), ("has_artifacts", False),
                         ("min_useful_ratio", 0.1),
                         ("max_roofline_fraction", 0.1)):
            rec = dict(with_art, **{key: bad})
            msgs = check_gate(g, rec, with_art)
            assert len(msgs) == 1 and key in msgs[0], (key, msgs)

    def test_gate_catches_regression_against_committed_baseline(self):
        """End-to-end on the real schema: take each committed baseline,
        bump a gated compile count, and the gate must fail on exactly
        that key (roofline is the one compile-rule-exempt gate)."""
        skipped = set()
        for g in ci_gate.GATES:
            with open(os.path.join(REPO, g.baseline)) as f:
                base = json.load(f)
            rule = next((r for r in g.rules if "compile" in r.key), None)
            if rule is None:
                skipped.add(g.name)
                continue
            rec = json.loads(json.dumps(base))
            node = rec
            *parents, leaf = rule.key.split(".")
            for p in parents:
                node = node[p]
            node[leaf] = node[leaf] + 3          # inject the regression
            msgs = check_gate(g, rec, base)
            assert len(msgs) == 1 and rule.key in msgs[0], (g.name, msgs)
            assert check_gate(g, base, base) == []
        assert skipped == {"roofline"}


class TestRunGates:
    @staticmethod
    def _stub_gate(tmp_path, record):
        def runner(out_json, smoke=True):
            with open(out_json, "w") as f:
                json.dump(record, f)
            return record
        return Gate("stub", str(tmp_path / "BENCH_stub.json"),
                    "BENCH_stub.ci.json",
                    rules=(Rule("compiles", "<="),), runner=runner)

    def test_update_baselines_then_gate_passes(self, tmp_path, capsys):
        art = tmp_path / "artifacts"
        gate = self._stub_gate(tmp_path, {"compiles": 3})
        assert ci_gate.run_gates((gate,), artifact_dir=str(art),
                                 update_baselines=True) == 0
        with open(tmp_path / "BENCH_stub.json") as f:
            assert json.load(f) == {"compiles": 3}
        assert os.path.exists(art / "BENCH_stub.ci.json")
        assert ci_gate.run_gates((gate,), artifact_dir=str(art)) == 0

    def test_missing_baseline_fails(self, tmp_path, capsys):
        gate = self._stub_gate(tmp_path, {"compiles": 3})
        assert ci_gate.run_gates((gate,),
                                 artifact_dir=str(tmp_path / "a")) == 1

    def test_regressed_record_fails_and_names_key(self, tmp_path, capsys):
        with open(tmp_path / "BENCH_stub.json", "w") as f:
            json.dump({"compiles": 1}, f)
        gate = self._stub_gate(tmp_path, {"compiles": 4})
        assert ci_gate.run_gates((gate,),
                                 artifact_dir=str(tmp_path / "a")) == 1
        out = capsys.readouterr().out
        assert "REGRESSION stub.compiles" in out
