"""End-to-end driver: train the ~100M-parameter config for a few hundred
steps with checkpoint/restart, doorbell-batched gradient planning and
traffic telemetry (wraps repro.launch.train).

Full run (CPU, ~10-20 min):
    PYTHONPATH=src python examples/train_100m.py
Smoke run:
    PYTHONPATH=src python examples/train_100m.py --steps 5 --seq 64
"""
import argparse
import json

from repro.launch.train import run


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="/tmp/reconic_100m_ckpt")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    res = run("train-100m", steps=args.steps, batch=args.batch,
              seq=args.seq, ckpt_dir=args.ckpt_dir, resume=args.resume,
              log_every=10, lr=1e-3, data_cycle=8)
    print(json.dumps(res, indent=1))
    assert res["last_loss"] < res["first_loss"]
    print("OK — loss", f"{res['first_loss']:.3f} -> {res['last_loss']:.3f}")


if __name__ == "__main__":
    main()
