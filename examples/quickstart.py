"""Quickstart: train a tiny LM for a few steps, then generate.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig
from repro.configs.registry import get_config
from repro.data.pipeline import DataConfig, SyntheticPipeline
from repro.models import init_params
from repro.serve import greedy_generate
from repro.train import init_adam, make_train_step


def main():
    cfg = get_config("tiny")
    tcfg = TrainConfig(learning_rate=3e-3, warmup_steps=2, total_steps=40)
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = init_adam(params)
    step = jax.jit(make_train_step(cfg, tcfg))
    pipe = SyntheticPipeline(DataConfig(seed=0, vocab_size=cfg.vocab_size,
                                        batch=4, seq_len=64))

    print(f"model: {cfg.name}  params={cfg.param_count()/1e6:.2f}M")
    batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(0).items()}
    for i in range(30):
        loss, params, opt = step(params, opt, batch)
        if i % 5 == 0:
            print(f"step {i:3d}  loss {float(loss):.4f}")

    prompt = batch["tokens"][:2, :8]
    out = greedy_generate(params, cfg, prompt, max_new=8, max_seq=64)
    print("prompt :", prompt.tolist())
    print("greedy :", out.tolist())
    print("OK")


if __name__ == "__main__":
    main()
