"""Networked systolic-array matrix multiplication — the paper's §IV-C
lookaside-compute example (Fig 6), step for step.

Peer 1 holds the matrices ("data node"); peer 2 is the RecoNIC node whose
lookaside kernel (the Pallas systolic MM, = the TPU MXU) computes. The
host CPU drives the 8-step workflow:

  (1) init + connection setup          (5) read-completion CQEs
  (2) build WQEs in the SQ             (6) control msg -> LC kernel
  (3) ring the SQ doorbell ONCE        (7) poll kernel status FIFO
  (4) wait on CQ doorbells             (8) results ready, next request

    PYTHONPATH=src python examples/networked_matmul.py
"""
import time

import jax.numpy as jnp
import numpy as np

from repro.core.lookaside import ControlMsg, LookasideBlock
from repro.core.memory import BufferPool
from repro.core.rdma import Opcode, RDMAEngine, WQE
from repro.kernels import ops as kops

M = 32          # matrix dim (the Pallas kernel pads to MXU-aligned tiles)
DATA_PEER, NIC_PEER = 0, 1


def main():
    # ---- (1) system init + "connection" setup ---------------------------
    eng = RDMAEngine(n_peers=2, pool_size=4 * M * M + 1024)
    # compute blocks share the engine (paper §I); the LC block rides the
    # NIC peer and sees memory through an LCContext
    lc = LookasideBlock(eng, peer=NIC_PEER)
    data_pool = BufferPool(eng, DATA_PEER)
    nic_pool = BufferPool(eng, NIC_PEER)

    rng = np.random.default_rng(0)
    A = rng.normal(size=(M, M)).astype(np.float32)
    B = rng.normal(size=(M, M)).astype(np.float32)
    a_src = data_pool.alloc(M * M)
    b_src = data_pool.alloc(M * M)
    data_pool.write(a_src, A.reshape(-1))
    data_pool.write(b_src, B.reshape(-1))
    print(f"(1) peer{DATA_PEER} holds A,B ({M}x{M}); "
          f"peer{NIC_PEER} is the RecoNIC compute node")

    a_dst = nic_pool.alloc(M * M)
    b_dst = nic_pool.alloc(M * M)
    c_dst = nic_pool.alloc(M * M)
    qp = eng.create_qp(NIC_PEER, DATA_PEER)
    eng.create_qp(DATA_PEER, NIC_PEER)

    # ---- (2)+(3) WQEs in SQ, ONE doorbell for the batch ------------------
    eng.post_send(qp, WQE(Opcode.READ, qp.qp_num, 1, local_addr=a_dst.base,
                          remote_addr=a_src.base, length=M * M,
                          rkey=a_src.rkey))
    eng.post_send(qp, WQE(Opcode.READ, qp.qp_num, 2, local_addr=b_dst.base,
                          remote_addr=b_src.base, length=M * M,
                          rkey=b_src.rkey))
    d0 = eng.transport.dispatch_count
    eng.ring_sq_doorbell(qp)
    print(f"(2)(3) 2 READ WQEs posted, doorbell rung once "
          f"(dispatches: {eng.transport.dispatch_count - d0})")

    # ---- (4)+(5) poll CQ ---------------------------------------------------
    cqes = eng.poll_cq(qp)
    assert len(cqes) == 2 and all(c.status.value == "success" for c in cqes)
    print(f"(4)(5) {len(cqes)} read completions")

    # ---- (6) control message -> systolic-array kernel ----------------------
    def systolic_mm_kernel(ctx, a_addr, b_addr, c_addr, m):
        x = ctx.load(a_addr, m * m).reshape(m, m)
        y = ctx.load(b_addr, m * m).reshape(m, m)
        z = np.asarray(kops.matmul(jnp.asarray(x), jnp.asarray(y)))
        ctx.store(c_addr, z.reshape(-1))
        return c_addr

    lc.register(1, systolic_mm_kernel, "systolic_mm")
    t0 = time.perf_counter()
    lc.dispatch(ControlMsg(1, (a_dst.base, b_dst.base, c_dst.base, M),
                           tag=99))
    # ---- (7) poll the status FIFO ------------------------------------------
    status = lc.poll(1)
    assert status is not None and status.ok
    print(f"(6)(7) kernel done in {(time.perf_counter()-t0)*1e3:.1f} ms, "
          f"status tag={status.tag} result@{status.result_addr}")

    # ---- (8) verify + done --------------------------------------------------
    C = nic_pool.read(c_dst).reshape(M, M)
    err = float(np.abs(C - A @ B).max())
    print(f"(8) max |C - A@B| = {err:.2e}")
    assert err < 1e-3
    print("OK — Fig 6 workflow reproduced")


if __name__ == "__main__":
    main()
