"""libreconic-style RDMA verbs walkthrough (paper §IV-B):

READ / WRITE / SEND-RECV / batch READ / batch WRITE — each in both
single-request and batch-requests doorbell modes, with QPs on host_mem or
dev_mem (the `-l` option of the paper's examples), plus engine telemetry.

    PYTHONPATH=src python examples/rdma_verbs_demo.py
"""
import numpy as np

from repro.core.rdma import (DoorbellCoalescer, Opcode, RDMAEngine, WQE)
from repro.core.rdma.simulator import simulate_rdma
from repro.core.rdma.verbs import Placement


def main():
    eng = RDMAEngine(n_peers=2, pool_size=8192)
    server, client = 1, 0
    qp = eng.create_qp(client, server)
    rqp = eng.create_qp(server, client)
    mr = eng.register_mr(server, 0, 4096)
    eng.write_buffer(server, 0, np.arange(256, dtype=np.float32))

    # -- READ (single-request) -------------------------------------------
    eng.post_send(qp, WQE(Opcode.READ, qp.qp_num, 1, local_addr=0,
                          remote_addr=0, length=64, rkey=mr.rkey))
    eng.ring_sq_doorbell(qp)
    print("READ  :", eng.poll_cq(qp)[0].status.value,
          eng.read_buffer(client, 0, 4))

    # -- WRITE -------------------------------------------------------------
    eng.write_buffer(client, 128, np.full(32, 3.5, np.float32))
    eng.post_send(qp, WQE(Opcode.WRITE, qp.qp_num, 2, local_addr=128,
                          remote_addr=512, length=32, rkey=mr.rkey))
    eng.ring_sq_doorbell(qp)
    print("WRITE :", eng.poll_cq(qp)[0].status.value,
          eng.read_buffer(server, 512, 4))

    # -- SEND / RECV (two-sided, with immediate) ---------------------------
    eng.post_recv(rqp, WQE(Opcode.RECV, rqp.qp_num, 7, local_addr=1024,
                           length=16))
    eng.post_send(qp, WQE(Opcode.SEND_IMM, qp.qp_num, 3, local_addr=0,
                          length=16, imm=0x1234))
    eng.ring_sq_doorbell(qp)
    rc = eng.poll_cq(rqp)[0]
    print(f"SEND  : responder got {rc.byte_len}B imm=0x{rc.imm:x}")

    # -- BATCH READ: n WQEs, ONE doorbell (paper's batch-requests) --------
    d0 = eng.transport.dispatch_count
    with DoorbellCoalescer(eng, qp, flush_threshold=50) as db:
        for i in range(50):
            db.post(WQE(Opcode.READ, qp.qp_num, 100 + i,
                        local_addr=2048 + i, remote_addr=i, length=1,
                        rkey=mr.rkey))
    print(f"BATCH READ: 50 WQEs -> "
          f"{eng.transport.dispatch_count - d0} dispatch(es), "
          f"{len(eng.poll_cq(qp, 64))} completions")

    # -- timing model: what batching buys on the paper's hardware ---------
    for payload in (4096, 16384, 32768):
        s = simulate_rdma("read", payload, 1)
        b = simulate_rdma("read", payload, 50)
        print(f"model {payload//1024:3d}KB: single "
              f"{s.throughput_bps/1e9:5.1f} Gb/s -> batch "
              f"{b.throughput_bps/1e9:5.1f} Gb/s "
              f"({b.throughput_bps/s.throughput_bps:.1f}x)")

    # -- CONCURRENT DOORBELLS: two QPs share the engine fairly ------------
    # The engine is shared (the paper's key flexibility point), so a deep
    # SQ could starve a shallow one. Ring with defer=True, then one flush
    # interleaves both windows round-robin under a WQE budget.
    deep = eng.create_qp(client, server)           # 24 pending WQEs
    shallow = eng.create_qp(client, server, weight=1)
    eng.scheduler, eng.flush_budget = "rr", 8
    for i in range(24):
        eng.post_send(deep, WQE(Opcode.READ, deep.qp_num, i,
                                local_addr=4096 + i, remote_addr=i,
                                length=1, rkey=mr.rkey))
    for i in range(4):
        eng.post_send(shallow, WQE(Opcode.READ, shallow.qp_num, 500 + i,
                                   local_addr=4200 + i, remote_addr=i,
                                   length=1, rkey=mr.rkey))
    eng.ring_sq_doorbell(deep, defer=True)
    eng.ring_sq_doorbell(shallow, defer=True)
    counts = eng.flush_doorbells()                 # ONE scheduled batch
    print(f"2-QP flush: deep got {counts[deep.qp_num]}/8, "
          f"shallow got {counts[shallow.qp_num]}/8 "
          f"(rr — the shallow QP is not starved)")
    while deep.pending() or shallow.pending():     # drain the leftovers
        eng.flush_doorbells()
    print(f"2-QP done : deep {len(eng.poll_cq(deep, 64))} CQEs in order, "
          f"shallow {len(eng.poll_cq(shallow, 64))} CQEs, "
          f"service={eng.stats['qp_service']}")
    eng.scheduler, eng.flush_budget = "rr", None

    # -- LOOKASIDE OFFLOAD: one host QP + one LC kernel share a flush ------
    # The compute blocks are CLIENTS of the same engine (paper §I): the
    # registered systolic_mm kernel RDMA-reads A,B from the server,
    # computes on the NIC, and RDMA-writes C back — its WQEs ride the
    # same descriptor tables as the host QP's verbs traffic, scheduled
    # by deficit round-robin under a budget.
    import jax.numpy as jnp

    from repro.core.lookaside import ControlMsg, LookasideBlock
    from repro.kernels.lc_offload import MM_WORKLOAD, register_default_kernels
    from repro.kernels.ref import ref_matmul

    eng.scheduler, eng.flush_budget = "drr", 8
    blk = LookasideBlock(eng, peer=client, scratch_base=6144)
    register_default_kernels(blk)
    blk.eager_writeback = False       # StatusMsg rides the write-back CQE

    host_qp = eng.create_qp(client, server)
    for i in range(6):                # concurrent host verbs traffic
        eng.post_send(host_qp, WQE(Opcode.READ, host_qp.qp_num, 700 + i,
                                   local_addr=5000 + i, remote_addr=i,
                                   length=1, rkey=mr.rkey))
    eng.ring_sq_doorbell(host_qp, defer=True)      # armed, not flushed

    i0 = eng.stats["transport"]["interleaved_batches"]
    m = 8
    blk.dispatch(ControlMsg(MM_WORKLOAD,
                            (server, mr.rkey, 0, 64, 2048, m, m, m),
                            tag=42))
    print(f"LC mm  : kernel done, status deferred "
          f"(poll={blk.poll(MM_WORKLOAD)}) — write-back CQE pending")
    eng.flush_doorbells()             # host-driven flush completes it
    st = blk.poll(MM_WORKLOAD)
    A = eng.read_buffer(server, 0, m * m).reshape(m, m)
    B = eng.read_buffer(server, 64, m * m).reshape(m, m)
    C = eng.read_buffer(server, 2048, m * m).reshape(m, m)
    err = float(np.abs(
        C - np.asarray(ref_matmul(jnp.asarray(A), jnp.asarray(B)))).max())
    while host_qp.pending():
        eng.flush_doorbells()
    print(f"LC mm  : ok={st.ok} tag={st.tag} |C-A@B|={err:.1e}; "
          f"{eng.stats['transport']['interleaved_batches'] - i0} "
          f"interleaved flush(es), lc_service="
          f"{eng.stats['lc_service']}, host got "
          f"{len(eng.poll_cq(host_qp, 64))} CQEs alongside")
    assert st.ok and err == 0.0
    eng.scheduler, eng.flush_budget = "rr", None

    # -- STREAMING RX (§IV-D): packets off the MAC, no ControlMsg ----------
    # Non-RDMA packets land in a device-resident RX ring (the ingress
    # classifier splits RoCEv2 traffic off to the RDMA engine);
    # LCKernel.stream() drains the ring in bursts — each burst's gather
    # is ONE descriptor-table execution, and with pipeline_depth > 1
    # burst i+1's gather is armed while burst i parses, so fetches and
    # write-backs share a flush (watch stats["lc_pipeline"]).
    from repro.core.streaming import RXRing, TrafficRouter, make_roce_header
    from repro.kernels.lc_offload import STREAM_PARSER_WORKLOAD

    sblk = LookasideBlock(eng, peer=client, scratch_base=4096,
                          scratch_size=2048, pipeline_depth=2,
                          eager_writeback=False)
    register_default_kernels(sblk)
    ring = RXRing(eng, peer=client, base=8192 - 16 * 64, depth=16)
    meta_mr = eng.register_mr(server, 3072, 16 * 4)
    sk = sblk.attach_ring(STREAM_PARSER_WORKLOAD, ring, out_peer=server,
                          out_rkey=meta_mr.rkey, out_base=3072, burst=4)
    router = TrafficRouter(rx_ring=ring)
    headers = np.stack([make_roce_header(4, 99, is_rdma=(i % 2 == 0))
                        for i in range(10)])
    counts = router.ingest_packets(headers)     # RDMA share bypasses ring
    consumed = sk.stream()                      # batched ring drain
    meta = eng.read_buffer(server, 3072, consumed * 4).reshape(-1, 4)
    print(f"STREAM : ingested {counts}, parsed {consumed} off the ring "
          f"(meta rows all non-RDMA: {not meta[:, 0].any()}), "
          f"pipeline={eng.stats['lc_pipeline']['head']}/"
          f"{eng.stats['lc_pipeline']['tail']} done, ring "
          f"occupancy peak {ring.stats['peak_occupancy']}")
    assert consumed == counts["streamed"] and not meta[:, 0].any()

    # -- MATCH→ACTION DISPATCH PLANE: per-packet handler routing -----------
    # The streaming path above hardwires ONE parser consuming the whole
    # ring. The dispatch plane is the multi-tenant version (the paper's
    # Vitis Networking P4 block): a MatchTable routes each ingress
    # packet by its parsed fields — RoCEv2 to the RDMA engine, ctrl
    # traffic (port 9000) to the parser handler, bulk traffic (port
    # 9100) to the int8-quantize handler — and the StreamDispatcher
    # demuxes the shared ring into per-handler sub-bursts whose operand
    # gathers all ride ONE descriptor table per flush. Both handlers
    # write class-mirrored output rings; host verbs traffic can share
    # the very same flushes (the engine stays one shared machine).
    from repro.core.streaming import (Drop, Forward, Handler, MatchTable,
                                      StreamDispatcher)
    from repro.kernels.lc_offload import (QUANT_ROW, STREAM_QUANT_WORKLOAD)

    # client pool layout: sblk scratch is 4096..6144 and the streaming
    # ring above sits at 7168..8192 — this ring takes 6144..7168
    dring = RXRing(eng, peer=client, base=6144, depth=16)
    dmeta_mr = eng.register_mr(server, 3328, 16 * 4)
    dquant_mr = eng.register_mr(server, 3392, 16 * QUANT_ROW)
    table = (MatchTable(default=Drop())
             .add(Forward(), priority=10, is_rdma=1)
             .add(Handler(STREAM_PARSER_WORKLOAD), udp_dport=9000)
             .add(Handler(STREAM_QUANT_WORKLOAD), udp_dport=9100))
    disp = StreamDispatcher(sblk, dring, table, burst=4)
    disp.register_handler(STREAM_PARSER_WORKLOAD, server, dmeta_mr.rkey,
                          3328)
    disp.register_handler(STREAM_QUANT_WORKLOAD, server, dquant_mr.rkey,
                          3392)
    drouter = TrafficRouter(rx_ring=dring, table=table)

    mixed = np.stack([make_roce_header(4, i) if i % 3 == 0
                      else make_roce_header(0, i, is_rdma=False,
                                            dport=9000 if i % 3 == 1
                                            else 9100)
                      for i in range(12)])
    # host verbs traffic armed alongside: one flush serves everything
    # (local_addr 3000.. is outside every scratch/ring region)
    for i in range(4):
        eng.post_send(host_qp, WQE(Opcode.READ, host_qp.qp_num, 900 + i,
                                   local_addr=3000 + i, remote_addr=i,
                                   length=1, rkey=mr.rkey))
    eng.ring_sq_doorbell(host_qp, defer=True)
    dp = eng.stats["dispatch"]           # engine-wide ledger: deltas
    r0, m0 = dp["dispatch_rounds"], dp["dispatch_mixed_rounds"]
    p0 = {n: c["pkts"] for n, c in dp["classes"].items()}
    dcounts = drouter.ingest_packets(mixed)
    dconsumed = disp.service()
    print(f"DISPATCH: ingested {dcounts} via the match table, "
          f"{dconsumed} pkts demuxed to "
          f"{ {n: c['pkts'] - p0.get(n, 0) for n, c in dp['classes'].items()} } "
          f"in {dp['dispatch_rounds'] - r0} round(s) "
          f"({dp['dispatch_mixed_rounds'] - m0} mixed — both handlers' "
          f"gathers in one flush), host CQEs alongside: "
          f"{len(eng.poll_cq(host_qp, 64))}")
    assert dconsumed == dcounts["streamed"] == 8
    assert dp["dispatch_mixed_rounds"] - m0 >= 1

    # -- SERVICE CHAIN: a MatchTable action that is a kernel PIPELINE ------
    # The dispatch plane generalized (BALBOA-style service chaining): a
    # table entry can name a Chain of lookaside kernels, where stage N's
    # RDMA write-back region IS stage N+1's operand-fetch source — no
    # host hop between stages, every stage's gathers and write-backs
    # riding the engine's shared shape-bucketed descriptor tables. The
    # production pipeline below is gradient egress: rows stream through
    # compress→checksum (int8 wire bytes byte-identical to
    # kops.compress(chunk=64), integrity stamps computed FROM those wire
    # bytes by the next stage), while host verbs traffic armed on the
    # same engine shares the very same flushes.
    from repro.core.streaming import GradEgressChain
    from repro.kernels import ops as kops

    geng = RDMAEngine(n_peers=2, pool_size=1 << 15, scheduler="drr",
                      flush_budget=16)
    chain = GradEgressChain(geng, data_peer=server, ring_base=1024,
                            out_base=4096, lc_peer=client,
                            scratch_base=1 << 14, scratch_size=1 << 14,
                            depth=16, burst=8)
    cqp = geng.create_qp(client, server)
    cmr = geng.register_mr(server, 0, 512)
    for i in range(4):                  # host verbs armed alongside
        geng.post_send(cqp, WQE(Opcode.READ, cqp.qp_num, 800 + i,
                                local_addr=700 + i, remote_addr=i,
                                length=1, rkey=cmr.rkey))
    geng.ring_sq_doorbell(cqp, defer=True)
    gflat = np.random.default_rng(3).normal(size=500).astype(np.float32)
    q, s, csum, resid = chain.compress(gflat, np.zeros(500, np.float32))
    kq, ks, _ = kops.compress(jnp.asarray(gflat), chunk=64)
    cparity = (np.array_equal(q, np.asarray(kq))
               and np.array_equal(s, np.asarray(ks)))
    cled = geng.stats["dispatch"]["chains"]["grad_egress"]
    print(f"CHAIN : compress→checksum egress of {q.shape[0]} rows in "
          f"{cled['bursts']} burst(s): {cled['stage_invocations']} stage "
          f"invocations / {cled['wqes']} chain WQEs, wire parity vs "
          f"kops.compress={cparity}, checksums "
          f"ok={GradEgressChain.verify_checksums(q, s, csum)}, host CQEs "
          f"alongside: {len(geng.poll_cq(cqp, 64))}")
    assert cparity and cled["stages"] == 2
    assert cled["completed_pkts"] == q.shape[0]
    assert GradEgressChain.verify_checksums(q, s, csum)

    # -- RELIABILITY: a lossy wire behind the same verbs (paper §III-A) ----
    # RoCEv2 RC semantics: every WQE transmission gets a PSN, a seeded
    # FaultInjector at the transport boundary loses 5% of them (plus
    # duplicates and corruption), and the go-back-N layer retransmits
    # until the bytes land — the host sees only SUCCESS CQEs, in posting
    # order, and a ledger of what the wire did. A stalled peer exhausts
    # the bounded retry budget into TERMINAL error CQEs (never an
    # exception), and recover_qp reopens the QP on a fresh PSN epoch.
    from repro.core.rdma import (CQEStatus, FaultInjector, QPState,
                                 ReliabilityConfig)

    reng = RDMAEngine(n_peers=2, pool_size=4096, flush_budget=8)
    injector = reng.install_fault_injector(
        FaultInjector(seed=7, drop=0.05, duplicate=0.02, corrupt=0.02),
        ReliabilityConfig(retry_cnt=8))
    rqp2 = reng.create_qp(client, server)
    rmr = reng.register_mr(server, 0, 2048)
    reng.write_buffer(client, 0, np.arange(512, dtype=np.float32))
    for i in range(32):
        reng.post_send(rqp2, WQE(Opcode.WRITE, rqp2.qp_num, i,
                                 local_addr=16 * i, remote_addr=16 * i,
                                 length=16, rkey=rmr.rkey))
    reng.ring_sq_doorbell(rqp2, defer=True)
    cqes = []
    while rqp2.pending_count or reng._reliability.outstanding():
        reng.flush_doorbells()
        cqes.extend(reng.poll_cq(rqp2, 64))
    rel = reng.stats["reliability"]
    ok = (np.array_equal(reng.read_buffer(server, 0, 512),
                         np.arange(512, dtype=np.float32))
          and [c.wr_id for c in cqes] == list(range(32)))
    print(f"RELIAB : 32 WRITEs over a 5%-loss wire -> parity={ok}, "
          f"ledger: acks={rel['acks']} retx={rel['retransmits']} "
          f"drops={rel['dropped']} naks={rel['naks']} "
          f"dup_suppressed={rel['dup_suppressed']}")
    assert ok and rel["acks"] == 32

    injector.stall_peer(server)          # the far side goes dark
    retx_before_stall = rel["retransmits"]
    reng.post_send(rqp2, WQE(Opcode.WRITE, rqp2.qp_num, 99, local_addr=0,
                             remote_addr=0, length=16, rkey=rmr.rkey))
    reng.ring_sq_doorbell(rqp2, defer=True)
    dead_cqes = []
    while not dead_cqes:
        reng.flush_doorbells()
        dead_cqes.extend(reng.poll_cq(rqp2))
    print(f"RELIAB : stalled peer -> {dead_cqes[0].status.value} after "
          f"{rel['retransmits'] - retx_before_stall} retransmissions, QP "
          f"{rqp2.state.value}, qp_errors={rel['qp_errors']}")
    assert dead_cqes[0].status is CQEStatus.RETRY_EXC_ERROR
    injector.unstall_peer(server)
    reng.recover_qp(rqp2)
    reng.post_send(rqp2, WQE(Opcode.WRITE, rqp2.qp_num, 100, local_addr=0,
                             remote_addr=1024, length=16, rkey=rmr.rkey))
    reng.ring_sq_doorbell(rqp2)
    print(f"RELIAB : recovered -> {reng.poll_cq(rqp2)[0].status.value}, "
          f"QP {rqp2.state.value}, recoveries={rel['recovered']}")
    assert rqp2.state is QPState.RTS

    # -- KV-SERVE: decode workers as transport clients ---------------------
    # Disaggregated KV-cache serving over the same verbs: KV pages are
    # MRs in a remote pool, a decode tenant fetches them with one-sided
    # READs on its own QP (weight = SLO tier), and a compressed pool
    # moves quantize-packed pages — 64/33 fewer wire words. Migration is
    # ONE doorbell batch of READs that evicts a source page only after
    # its SUCCESS CQE, so a lossy wire can never lose a page.
    from repro.serve.kv_cache import (PagedKVPool, RemoteKVClient,
                                      migrate_sequence, packed_page_words)

    keng = RDMAEngine(n_peers=2, pool_size=8192, scheduler="drr")
    kpool = PagedKVPool(keng, server, page_elems=256, max_pages=8)
    krows = np.random.default_rng(0).standard_normal(
        (2, 256)).astype(np.float32)
    for row in krows:
        kpool.write_page(kpool.append_page(seq_id=0), row)
    kclient = RemoteKVClient(keng, client, kpool)
    gold = kclient.register_tenant("gold", weight=2)
    kb0 = keng.stats["qp_bytes"].get(gold.qp.qp_num, 0)
    fetched = kclient.complete(kclient.fetch_sequence(gold, 0))
    kwire = keng.stats["qp_bytes"][gold.qp.qp_num] - kb0
    print(f"KV-SERVE: tenant '{gold.name}' (weight={gold.weight}) "
          f"fetched {len(kpool.pages[0])} pages = {kwire} words over "
          f"one-sided READs, parity={bool((fetched == krows).all())}")
    assert (fetched == krows).all() and kwire == 2 * 256

    zpool = PagedKVPool(keng, server, page_elems=256, max_pages=4,
                        compressed=True)
    zpool.write_page(zpool.append_page(seq_id=0), krows[0])
    zclient = RemoteKVClient(keng, client, zpool)
    bulk = zclient.register_tenant("bulk", weight=1)
    zb0 = keng.stats["qp_bytes"].get(bulk.qp.qp_num, 0)
    zfetched = zclient.complete(zclient.fetch_sequence(bulk, 0))
    zwire = keng.stats["qp_bytes"][bulk.qp.qp_num] - zb0
    zerr = float(np.abs(zfetched[0] - krows[0]).max())
    print(f"KV-SERVE: compressed pool moved {zwire} words for a 256-elem "
          f"page (= {packed_page_words(256)}: scales + packed int8 "
          f"pairs) -> wire ratio {256 / zwire:.2f}x, "
          f"max dequant err {zerr:.3f}")
    assert zwire == 132

    kdst = PagedKVPool(keng, client, page_elems=256, max_pages=8)
    kqp = keng.create_qp(client, server)
    moved = migrate_sequence(keng, TrafficRouter(), kpool, kdst, 0, kqp)
    print(f"KV-SERVE: migrated {moved} pages in ONE doorbell batch "
          f"(src evicted on SUCCESS CQEs only), "
          f"ledger={keng.stats['kv_serve']}")
    assert moved == 2 and kpool.allocated == 0

    # -- COLLECTIVES: gradient all-reduce as scheduled verbs ---------------
    # Training comm on the SAME engine kind serving uses: a ring
    # all-reduce is 2(n-1) rounds of one-sided chunk READs, one deferred
    # doorbell flush per round, host partial-reduces between rounds.
    from repro.train.collectives import RDMACollective, ideal_wire_words

    ceng = RDMAEngine(n_peers=4, pool_size=4096, scheduler="drr")
    coll = RDMACollective(ceng, 4, algorithm="ring", pipeline_depth=2)
    crng = np.random.default_rng(1)
    grads = [[crng.integers(-8, 9, 256).astype(np.float32)
              for _ in range(4)] for _ in range(2)]     # 2 buckets
    summed = coll.all_reduce_buckets(grads)
    parity = all(
        np.array_equal(summed[b][p], np.sum(grads[b], axis=0))
        for b in range(2) for p in range(4))
    led = ceng.stats["collectives"]
    print(f"COLLECTIVES: ring all-reduce of 2 buckets x 256 words over "
          f"4 peers: {led['rounds']} rounds in {led['flushes']} flushes "
          f"({led['overlapped_flushes']} overlapped), "
          f"{led['wire_words']} wire words "
          f"(ideal {2 * ideal_wire_words('ring', 4, 256)}), "
          f"parity={parity}")
    assert parity and led["overlapped_flushes"] > 0
    assert led["wire_words"] == 2 * ideal_wire_words("ring", 4, 256)

    # -- AUTOTUNE: the transport tunes its own knobs -----------------------
    # Every knob above (ring_burst=32, pipeline_depth, flush_budget, the
    # per-QP window) started life hand-picked. The engine now learns
    # both halves online: a decaying (slots, chunk) histogram built from
    # its OWN dispatch stream replaces replayed `bucket_hist` dumps as
    # the prewarm source, and a seeded coordinate sweep re-measures the
    # knobs against the engine's own traffic shape, scoring trials on
    # deterministic flush/WQE counts through the doorbell cost model —
    # never wall-clock — so the chosen point is reproducible.
    from repro.core.rdma.autotune import AutoTuner, TuningGrid

    tuner = AutoTuner(eng, seed=7, passes=1, rows=64,
                      grid=TuningGrid(ring_burst=(16, 32, 64),
                                      pipeline_depth=(1, 2, 4),
                                      flush_budget=(None,),
                                      qp_window=(None, 8)))
    chosen = tuner.sweep()                  # installs via apply_tuning()
    at = eng.stats["autotune"]
    print(f"AUTOTUNE: {at['trials']} trials -> burst={chosen.ring_burst} "
          f"depth={chosen.pipeline_depth} window={chosen.qp_window} "
          f"({at['improvement']:.2f}x over hand-picked defaults)")
    assert at["improvement"] >= 1.0 and eng.tuning == chosen

    # A fresh engine prewarms straight off the live engine's learned
    # histogram — widened buckets included — so its first real batch is
    # a descriptor-cache hit instead of a cold compile.
    warm = RDMAEngine(n_peers=2, pool_size=eng.pool_size)
    n_warm = warm.transport.prewarm(eng.transport.bucket_learner)
    print(f"AUTOTUNE: fresh engine prewarmed {n_warm} learned buckets "
          f"({eng.transport.stats['learned_buckets']} live, "
          f"{eng.transport.stats['bucket_merges']} merged, "
          f"{eng.transport.stats['bucket_decay_events']} decayed)")
    assert n_warm >= 1 and warm.transport.stats["cache_misses"] == 0

    # -- host_mem vs dev_mem placement (the -l flag) -----------------------
    eng.write_buffer(client, 0, np.ones(8, np.float32),
                     Placement.HOST_MEM)
    print("host_mem buffer:", eng.read_buffer(client, 0, 4,
                                              Placement.HOST_MEM))
    print("engine stats   :", eng.stats)
    print("OK")


if __name__ == "__main__":
    main()
