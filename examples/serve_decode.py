"""Batched serving with paged KV cache + RDMA page migration.

Serves a small model with batched requests (prefill -> decode), then
migrates a finished sequence's KV pages between peers as ONE doorbell
batch of RDMA READs — the disaggregated prefill/decode pattern.

    PYTHONPATH=src python examples/serve_decode.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.core.rdma import RDMAEngine
from repro.core.streaming.classifier import TrafficClass, TrafficRouter
from repro.models import init_caches, init_params
from repro.serve import decode_step, prefill_step
from repro.serve.kv_cache import PagedKVPool, migrate_sequence


def main():
    cfg = get_config("tiny")
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    batch, prompt_len, gen_len, max_seq = 8, 32, 16, 64
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size,
                                       (batch, prompt_len)), jnp.int32)

    # ---- prefill ---------------------------------------------------------
    caches = init_caches(cfg, batch, max_seq, jnp.float32)
    t0 = time.perf_counter()
    logits, caches = prefill_step(params, cfg, {"tokens": prompts}, caches)
    print(f"prefill: {batch} reqs x {prompt_len} tokens "
          f"in {(time.perf_counter()-t0)*1e3:.0f} ms")

    # ---- decode (continuous batch of 8) -----------------------------------
    step = jax.jit(lambda p, t, c, pos: decode_step(p, cfg, t, c, pos))
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
    t0 = time.perf_counter()
    outs = [tok]
    for i in range(gen_len - 1):
        logits, caches = step(params, tok, caches,
                              jnp.int32(prompt_len + i))
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        outs.append(tok)
    dt = time.perf_counter() - t0
    print(f"decode : {gen_len} steps, "
          f"{batch*(gen_len-1)/dt:.1f} tokens/s (batched)")
    print("sample :", jnp.concatenate(outs, 1)[0].tolist())

    # ---- KV page migration (prefill node -> decode node) -------------------
    eng = RDMAEngine(n_peers=2, pool_size=1 << 14)
    router = TrafficRouter()
    prefill_pool = PagedKVPool(eng, 0, page_elems=256, max_pages=16)
    decode_pool = PagedKVPool(eng, 1, page_elems=256, max_pages=16)
    for _ in range(4):   # 4 KV pages for sequence 7
        p = prefill_pool.append_page(seq_id=7)
        prefill_pool.write_page(p, rng.normal(size=256).astype(np.float32))
    qp = eng.create_qp(1, 0)
    eng.create_qp(0, 1)
    d0 = eng.transport.dispatch_count
    n = migrate_sequence(eng, router, prefill_pool, decode_pool, 7, qp)
    print(f"migrate: {n} KV pages prefill->decode, "
          f"{eng.transport.dispatch_count - d0} doorbell(s), "
          f"traffic={router.counters[TrafficClass.KV_PAGE]}")
    assert decode_pool.seq_len_pages(7) == 4
    print("OK")


if __name__ == "__main__":
    main()
