"""Disaggregated serving: prefill node publishes KV pages to a remote
pool, decode node fetches them over one-sided RDMA READs.

The full handoff on one engine: prefill fills the caches, the prefill
node publishes them as pages of a remote ``PagedKVPool``, and the decode
node — a ``RemoteKVClient`` tenant with its own QP — fetches them back
through the engine's shape-bucketed descriptor tables before decoding.
Decoded tokens are bit-identical to keeping the caches local, the fetch
moves each page byte over the wire ONCE (host staging would cross PCIe
twice), and a migration under a 10% seeded drop profile loses zero
pages.

    PYTHONPATH=src python examples/serve_decode.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.core.rdma import FaultInjector, RDMAEngine
from repro.core.streaming.classifier import TrafficClass, TrafficRouter
from repro.models import init_caches, init_params
from repro.serve import decode_step, prefill_step
from repro.serve.kv_cache import (PagedKVPool, RemoteKVClient,
                                  flatten_cache_leaves, migrate_sequence,
                                  unflatten_cache_leaves)

PAGE_ELEMS = 256


def main():
    cfg = get_config("tiny")
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    batch, prompt_len, gen_len, max_seq = 8, 32, 16, 64
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size,
                                       (batch, prompt_len)), jnp.int32)

    # ---- prefill (the prefill node's job) --------------------------------
    caches = init_caches(cfg, batch, max_seq, jnp.float32)
    t0 = time.perf_counter()
    logits, caches = prefill_step(params, cfg, {"tokens": prompts}, caches)
    print(f"prefill: {batch} reqs x {prompt_len} tokens "
          f"in {(time.perf_counter()-t0)*1e3:.0f} ms")

    # ---- publish -> fetch: caches through the remote KV pool -------------
    n_words = int(flatten_cache_leaves(caches).size)
    n_pages = -(-n_words // PAGE_ELEMS)
    eng = RDMAEngine(n_peers=2, pool_size=4 * n_pages * PAGE_ELEMS)
    router = TrafficRouter()
    pool = PagedKVPool(eng, 0, page_elems=PAGE_ELEMS, max_pages=n_pages)
    client = RemoteKVClient(eng, 1, pool, router=router)
    gold = client.register_tenant("decode-gold", weight=2)

    client.publish_caches(seq_id=0, caches=caches)
    ticket = client.fetch_sequence(gold, 0)
    fetched = client.complete(ticket)
    caches = unflatten_cache_leaves(fetched.reshape(-1), caches)
    wire = 4 * eng.stats["qp_bytes"][gold.qp.qp_num]
    print(f"handoff: {n_pages} pages ({n_words} words) published, fetched "
          f"over one-sided READs on tenant '{gold.name}' (weight="
          f"{gold.weight}): wire={wire}B, host staging would be "
          f"{2 * wire}B of PCIe, "
          f"traffic={router.counters[TrafficClass.KV_PAGE]}")

    # ---- decode on the FETCHED caches ------------------------------------
    step = jax.jit(lambda p, t, c, pos: decode_step(p, cfg, t, c, pos))
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
    t0 = time.perf_counter()
    outs = [tok]
    for i in range(gen_len - 1):
        logits, caches = step(params, tok, caches,
                              jnp.int32(prompt_len + i))
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        outs.append(tok)
    dt = time.perf_counter() - t0
    print(f"decode : {gen_len} steps, "
          f"{batch*(gen_len-1)/dt:.1f} tokens/s (batched, remote-fetched "
          "caches)")
    print("sample :", jnp.concatenate(outs, 1)[0].tolist())

    # ---- KV page migration on a LOSSY fabric ------------------------------
    meng = RDMAEngine(n_peers=2, pool_size=1 << 14)
    meng.install_fault_injector(FaultInjector(seed=13, drop=0.10))
    mrouter = TrafficRouter()
    prefill_pool = PagedKVPool(meng, 0, page_elems=PAGE_ELEMS, max_pages=16)
    decode_pool = PagedKVPool(meng, 1, page_elems=PAGE_ELEMS, max_pages=16)
    for _ in range(4):   # 4 KV pages for sequence 7
        p = prefill_pool.append_page(seq_id=7)
        prefill_pool.write_page(p, rng.normal(size=PAGE_ELEMS)
                                .astype(np.float32))
    qp = meng.create_qp(1, 0)
    d0 = meng.transport.dispatch_count
    n = migrate_sequence(meng, mrouter, prefill_pool, decode_pool, 7, qp,
                         max_flushes=128)
    rel = meng.stats["reliability"]
    print(f"migrate: {n}/4 KV pages prefill->decode over a 10%-loss wire "
          f"({rel['retransmits']} retransmission(s)), "
          f"{meng.transport.dispatch_count - d0} doorbell batch, "
          f"traffic={mrouter.counters[TrafficClass.KV_PAGE]}")
    assert decode_pool.seq_len_pages(7) == 4     # zero pages lost
    assert prefill_pool.allocated == 0           # evicted on SUCCESS only
    print("ledger :", meng.stats["kv_serve"])
    print("OK")


if __name__ == "__main__":
    main()
